"""Vectorized on-device token sampling — the decode epilogue's math.

Lives in the core layer (no serving dependencies) so ``PhaseEngine`` can
build sampler programs without importing serving; ``repro.serving.sampling``
re-exports these next to ``SamplingParams``.

PRNG discipline (preemption-safe by construction): token ``i`` of a request
is always drawn with ``fold_in(PRNGKey(seed), i)``.  The key stream is a
pure function of ``(seed, token index)`` — no mutable sampler state exists —
so a preempted request that re-prefills and teacher-forces its recorded
tokens resumes the stream at exactly the index it would have used had it
never been evicted.  Seeded sampling is therefore bit-identical across
eviction/restart cycles (the property tests/test_serving_api.py pins).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _filter_row(scaled: jnp.ndarray, top_k: jnp.ndarray, top_p: jnp.ndarray) -> jnp.ndarray:
    """Mask one temperature-scaled logit row to its top-k ∩ nucleus support.

    Everything outside the support becomes -inf, so the categorical draw
    places exactly zero mass there (the invariant the sampler tests assert).
    The top token always survives both truncations.
    """
    vocab = scaled.shape[-1]
    desc = jnp.sort(scaled)[::-1]
    # top-k threshold: the k-th largest scaled logit (k<=0 disables)
    k_eff = jnp.where(top_k > 0, jnp.minimum(top_k, vocab), vocab)
    kth = jnp.take(desc, k_eff - 1)
    # nucleus threshold: smallest prefix of the sorted distribution whose
    # mass reaches top_p — position i is kept iff the mass BEFORE it < p
    probs = jax.nn.softmax(desc)
    mass_before = jnp.cumsum(probs) - probs
    n_keep = jnp.maximum(jnp.sum(mass_before < top_p), 1)
    pth = jnp.take(desc, n_keep - 1)
    cut = jnp.maximum(kth, pth)
    return jnp.where(scaled >= cut, scaled, -jnp.inf)


def filter_logits(logits, temps, top_ks, top_ps):
    """Vectorized scale+truncate: (B, V) logits -> (B, V) masked scaled
    logits with -inf outside each slot's sampling support."""
    scaled = logits.astype(jnp.float32) / jnp.maximum(temps, 1e-6)[:, None]
    return jax.vmap(_filter_row)(scaled, top_ks, top_ps)


def sample_block_tokens(logits, seeds, step0s, temps, top_ks, top_ps):
    """Per-slot target tokens for every position of a speculative verify
    block: ``logits`` is (B, W, V) — the verify pass's logits at the W =
    k + 1 block positions — and the returned (B, W) int32 tokens are what
    sequential decode WOULD have drawn at each position.

    Position ``i`` of slot ``b`` is drawn with
    ``fold_in(PRNGKey(seeds[b]), step0s[b] + i)`` — exactly the key
    sequential decode uses for its ``step0s[b] + i``-th token — so the
    speculative accept rule (below) preserves the non-speculative stream
    bit-for-bit under sampling as well as greedy, and preemption replay
    keeps its determinism unchanged.
    """

    def per_pos(i, row_logits):  # row_logits: (B, V) at block position i
        return sample_tokens(row_logits, seeds, step0s + i, temps, top_ks, top_ps)

    w = logits.shape[1]
    return jax.vmap(per_pos, in_axes=(0, 1), out_axes=1)(jnp.arange(w), logits)


def accept_length(draft, targets) -> int:
    """The speculative accept rule: length of the longest draft prefix the
    verify targets confirm.

    ``draft[i]`` was proposed for the position whose true token (under the
    slot's SamplingParams) is ``targets[i]`` — the verify logits at block
    position ``i`` scored against the same PRNG key / greedy argmax plain
    decode would use.  Accepting exactly the leading run of matches (and
    emitting ``targets[a]`` as the correction token) therefore reproduces
    the non-speculative stream token-for-token: every emitted token IS the
    token sequential decode would have produced at that position.
    """
    a = 0
    for d, t in zip(draft, targets):
        if int(d) != int(t):
            break
        a += 1
    return a


def sample_tokens(logits, seeds, steps, temps, top_ks, top_ps):
    """Draw one token per slot on device.

    Args:
      logits: (B, V) float — the decode round's last-token logits.
      seeds:  (B,) int32 — per-request ``SamplingParams.seed32``.
      steps:  (B,) int32 — index of the token being drawn (= tokens already
        generated); the fold_in counter that makes replay deterministic.
      temps/top_ks/top_ps: (B,) per-slot sampling knobs; ``temp <= 0``
        selects greedy argmax for that slot.

    Returns (B,) int32 token ids.
    """
    greedy_toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    masked = filter_logits(logits, temps, top_ks, top_ps)

    def draw(row, seed, step):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        return jax.random.categorical(key, row).astype(jnp.int32)

    sampled = jax.vmap(draw)(masked, seeds, steps)
    return jnp.where(temps <= 0.0, greedy_toks, sampled)
