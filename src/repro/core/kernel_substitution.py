"""Per-cell analytic kernel costs for the kernel-substituted roofline.

The dry-run lowers each prefill/decode cell twice:

  * ``attn_impl='xla'``  — generic XLA attention: the compiled program a
    static (TeLLMe-style) deployment would run.  Its HLO-derived roofline is
    the paper-faithful BASELINE.
  * ``attn_impl='stub'`` — attention cores stubbed out; this module supplies
    the exact BlockSpec-derived cost of the phase-specialized Pallas RMs
    (kernels/costs.py).  stub-HLO + kernel analytic = the PD-Swap program.

Sharding model (launch/sharding_rules + layers/sharding rules):
  prefill: batch over dp, q-heads over tp (replicated when H % tp != 0).
  decode:  batch over dp, KV sequence over tp (flash-decoding split: every
           device streams S/tp of the cache; the cross-device LSE merge is
           a tiny collective already present in the stub HLO).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.configs.base import ModelConfig, ShapeCell
from repro.kernels.costs import (
    ZERO,
    KernelCost,
    decode_attention_cost,
    mlstm_chunk_cost,
    prefill_attention_cost,
    slstm_scan_cost,
)

_FULL_WINDOW = 1 << 30


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _layer_windows(cfg: ModelConfig) -> list[Optional[int]]:
    if cfg.sliding_window is None:
        return [None] * cfg.num_layers
    return [
        None if l in cfg.global_attn_layers else cfg.sliding_window
        for l in range(cfg.num_layers)
    ]


# Flash-attention training multipliers over the forward kernel: the backward
# re-streams q/k/v/o/do and recomputes the score tiles while producing
# dq/dk/dv (the standard FlashAttention-2 backward dataflow; same BlockSpec
# family as the forward kernel in kernels/prefill_attention).
TRAIN_FLOPS_MULT = 3.5  # fwd + bwd(2.5x, incl. in-kernel score recompute)
TRAIN_BYTES_MULT = 3.0  # fwd io + bwd reads(q,k,v,o,do) + writes(dq,dk,dv)


def kernel_costs_for_cell(cfg: ModelConfig, cell: ShapeCell, *, dp: int, tp: int) -> KernelCost:
    """Per-device Pallas-kernel cost of one phase step for this cell."""
    if cfg.family == "xlstm":
        # Attention-free: the phase RMs are the chunkwise-mLSTM and
        # sLSTM-scan kernels (prefill/train — decode is the O(1) recurrent
        # update, kept in XLA).  Ideal TP split of the head-state dim.
        if cell.kind == "decode":
            return ZERO
        b_loc = _ceil_div(cell.global_batch, dp)
        h, hd, d = cfg.num_heads, cfg.d_model // cfg.num_heads, cfg.d_model
        n_s = cfg.num_layers // cfg.slstm_every
        n_m = cfg.num_layers - n_s
        total = ZERO
        for _ in range(n_m):
            c = mlstm_chunk_cost(b_loc, h, cell.seq_len, hd)
            total = total + KernelCost(c.flops / tp, c.hbm_bytes / tp, c.vmem_bytes)
        for _ in range(n_s):
            c = slstm_scan_cost(b_loc, cell.seq_len, d, h)
            total = total + KernelCost(c.flops / tp, c.hbm_bytes / tp, c.vmem_bytes)
        if cell.kind == "train":
            total = KernelCost(total.flops * TRAIN_FLOPS_MULT,
                               total.hbm_bytes * TRAIN_BYTES_MULT, total.vmem_bytes)
        return total

    b_loc = _ceil_div(cell.global_batch, dp)
    h, hkv, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    total = ZERO

    if cell.kind in ("prefill", "train"):
        h_loc = h // tp if h % tp == 0 else h  # replicated when indivisible
        hkv_loc = max(hkv // tp, 1) if h % tp == 0 else hkv
        for w in _layer_windows(cfg):
            total = total + prefill_attention_cost(
                b_loc, h_loc, hkv_loc, cell.seq_len, d, window=w
            )
        if cfg.family == "encdec":
            senc = _ceil_div(cfg.encoder_seq, 128) * 128
            for _ in range(cfg.encoder_layers):  # encoder self-attn, non-causal
                total = total + prefill_attention_cost(
                    b_loc, h_loc, hkv_loc, senc, d, causal=False
                )
            for _ in range(cfg.num_layers):  # cross-attn: S queries x Senc keys
                total = total + prefill_attention_cost(
                    b_loc, h_loc, hkv_loc, cell.seq_len, d, causal=False, skv=senc
                )
        if cell.kind == "train":
            total = KernelCost(total.flops * TRAIN_FLOPS_MULT,
                               total.hbm_bytes * TRAIN_BYTES_MULT, total.vmem_bytes)
    else:  # decode
        s_loc = _ceil_div(cell.seq_len, tp)  # KV-sequence sharding
        for w in _layer_windows(cfg):
            w_loc = None if w is None else _ceil_div(min(w, cell.seq_len), tp)
            total = total + decode_attention_cost(b_loc, h, hkv, s_loc, d, window=w_loc)
        if cfg.family == "encdec":
            senc_loc = _ceil_div(_ceil_div(cfg.encoder_seq, 128) * 128, tp)
            for _ in range(cfg.num_layers):
                total = total + decode_attention_cost(b_loc, h, hkv, senc_loc, d)
    return total
