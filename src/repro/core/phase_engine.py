"""Phase-specialized compiled programs — the TPU analogue of the paper's
reconfigurable modules (DESIGN.md §2, C1).

On an FPGA a "configuration" is a bitstream; on TPU it is a compiled XLA
executable: fusion plan, kernel block shapes, layouts and collective
schedule.  ``PhaseEngine`` owns, for one (arch x mesh x shape):

  * ``prefill``        — token-parallel program (compute-optimized RM)
  * ``prefill_body``   — prefill through the LAST layer's attention
  * ``prefill_tail``   — last FFN + norm + logits (runs during the swap)
  * ``kv_relayout``    — the *swap itself*: prefill-layout KV -> decode-layout
                         cache (reshard + pad + optional int8 compression).
                         This is the physically-real analogue of the 45 ms
                         PCAP bitstream load.
  * ``decode``         — KV-streaming program (bandwidth-optimized RM)

Weights are never touched by the swap: both phase programs consume the same
param buffers with identical shardings — the paper's static region.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.layers.sharding import (
    DECODE_RULES,
    LONG_DECODE_RULES,
    MeshAxes,
    NULL_CTX,
    PREFILL_RULES,
    PartitionCtx,
)
from repro.models import get_model
from repro.launch.sharding_rules import params_shardings


@dataclasses.dataclass
class PhaseProgram:
    name: str
    fn: Callable  # jitted
    abstract_inputs: tuple = ()
    lowered: Any = None
    compiled: Any = None
    # Registry metadata, audited by the `program` analysis pass
    # (repro.analysis.progcheck): the donation DECLARED for this program —
    # recorded by PhaseEngine._program from the same tuple passed to
    # jax.jit(donate_argnums=...), so declaration and jit signature cannot
    # diverge — and the serving phase the program belongs to.
    donate_argnums: Tuple[int, ...] = ()
    phase: str = ""  # "prefill" | "decode" | "swap" | "sampler"

    def lower_and_compile(self, *args):
        args = args or self.abstract_inputs
        self.lowered = self.fn.lower(*args)
        self.compiled = self.lowered.compile()
        return self.compiled


def _mesh_axes(mesh: Optional[Mesh]) -> MeshAxes:
    if mesh is None:
        return MeshAxes()
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data")) or (None,)
    dp = dp[0] if len(dp) == 1 else dp
    return MeshAxes(dp=dp, tp="model" if "model" in names else None, fsdp="data" if "data" in names else None)


def make_pctx(mesh: Optional[Mesh], phase: str) -> PartitionCtx:
    rules = {"prefill": PREFILL_RULES, "decode": DECODE_RULES, "long_decode": LONG_DECODE_RULES}.get(
        phase, PREFILL_RULES
    )
    return PartitionCtx(mesh=mesh, axes=_mesh_axes(mesh), rules=rules)


class PhaseEngine:
    """Builds and caches the phase programs for one architecture."""

    def __init__(
        self,
        cfg: ModelConfig,
        mesh: Optional[Mesh] = None,
        *,
        max_len: int = 0,
        long_context: bool = False,
        kv_quant: Optional[str] = None,  # legacy knob: None | "int8" ((int8, scale) tuples)
        cache_layout: str = "contiguous",  # "contiguous" | "paged"
        kv_dtype: str = "fp",  # "fp" | "int8" | "int4" — quantized KV subsystem
    ):
        from repro.quant.kv_quant import assert_kv_dtype

        assert cache_layout in ("contiguous", "paged"), cache_layout
        assert_kv_dtype(kv_dtype)
        assert kv_quant is None or kv_dtype == "fp", (
            "kv_quant (legacy relayout-only int8) and kv_dtype (the quantized "
            "KV-cache subsystem) are mutually exclusive")
        self.cfg = cfg
        self.mesh = mesh
        self.api = get_model(cfg)
        self.max_len = max_len
        self.kv_quant = kv_quant
        self.kv_dtype = kv_dtype
        self.cache_layout = cache_layout
        self.decode_phase = "long_decode" if long_context else "decode"
        self.prefill_ctx = make_pctx(mesh, "prefill")
        self.decode_ctx = make_pctx(mesh, self.decode_phase)
        self._programs: Dict[str, PhaseProgram] = {}

    # ------------------------------------------------------------ helpers --

    def param_shardings(self, params_abstract):
        if self.mesh is None:
            return None
        return params_shardings(params_abstract, self.cfg, self.mesh, train=False)

    def _sd(self, pctx: PartitionCtx, *logical):
        return pctx.named_sharding(*logical)

    def _jit(self, fn, in_shardings=None, out_shardings=None, donate=()):
        if self.mesh is None:
            return jax.jit(fn, donate_argnums=donate)
        return jax.jit(fn, in_shardings=in_shardings, out_shardings=out_shardings, donate_argnums=donate)

    def _program(self, key: str, fn, *, in_shardings=None, out_shardings=None,
                 donate: Tuple[int, ...] = (), phase: str = "") -> PhaseProgram:
        """Jit ``fn`` and register it under ``key`` with its metadata.  The
        ONE construction path for phase programs: ``donate`` is both the
        ``jax.jit(donate_argnums=...)`` argument and the program's declared
        donation, so the registry the analysis pass audits reflects what the
        compiler was actually told."""
        prog = PhaseProgram(
            key,
            self._jit(fn, in_shardings=in_shardings,
                      out_shardings=out_shardings, donate=donate),
            donate_argnums=tuple(donate),
            phase=phase,
        )
        self._programs[key] = prog
        return prog

    @property
    def programs(self) -> Dict[str, PhaseProgram]:
        """The program registry (a copy): every phase program built so far,
        keyed by its cache signature — the surface the `program` analysis
        pass traces."""
        return dict(self._programs)

    # ----------------------------------------------------------- programs --

    def prefill_program(self, params_abstract, batch: int, seq: int, *, frames: bool = False) -> PhaseProgram:
        key = f"prefill:{batch}x{seq}"
        if key in self._programs:
            return self._programs[key]
        cfg, api, pctx = self.cfg, self.api, self.prefill_ctx

        if frames:
            def fn(params, tokens, frame_emb):
                return api.forward_prefill(params, tokens, cfg, pctx, frames=frame_emb)
        else:
            def fn(params, tokens):
                return api.forward_prefill(params, tokens, cfg, pctx)

        in_sh = None
        if self.mesh is not None:
            tok_sh = self._sd(pctx, "batch", "seq")
            in_sh = (self.param_shardings(params_abstract), tok_sh)
            if frames:
                in_sh = in_sh + (self._sd(pctx, "batch", "seq", "embed"),)
        return self._program(key, fn, in_shardings=in_sh, phase="prefill")

    def prefill_program_varlen(self, params_abstract, batch: int, seq: int) -> PhaseProgram:
        """Prefill compiled at bucket length ``seq`` for right-padded
        variable-length prompts: ``fn(params, tokens, last_pos)`` returns the
        logits of the prompt's true last token (causality keeps positions
        <= last_pos independent of the padding tail)."""
        key = f"prefill_varlen:{batch}x{seq}"
        if key in self._programs:
            return self._programs[key]
        cfg, pctx = self.cfg, self.prefill_ctx
        assert cfg.family == "transformer", "varlen prefill implemented for the transformer family"
        from repro.models import transformer as T

        def fn(params, tokens, last_pos):
            return T.forward_prefill(params, tokens, cfg, pctx, last_pos=last_pos)

        in_sh = None
        if self.mesh is not None:
            in_sh = (self.param_shardings(params_abstract), self._sd(pctx, "batch", "seq"), None)
        return self._program(key, fn, in_shardings=in_sh, phase="prefill")

    def prefill_split_programs_varlen(
        self, params_abstract, batch: int, seq: int
    ) -> Tuple[PhaseProgram, PhaseProgram]:
        """(body, tail) like ``prefill_split_programs`` but the tail takes
        ``last_pos`` — the overlap split for variable-length prompts."""
        key = f"prefill_split_varlen:{batch}x{seq}"
        if key in self._programs:
            body = self._programs[key]
            tail = self._programs[key + ":tail"]
            return body, tail
        cfg, pctx = self.cfg, self.prefill_ctx
        assert cfg.family == "transformer", "overlap split implemented for the transformer family"
        from repro.models import transformer as T

        def body_fn(params, tokens):
            return T.forward_prefill(params, tokens, cfg, pctx, split_tail=True)

        def tail_fn(params, x_mid, last_pos):
            return T.prefill_tail(params, x_mid, cfg, pctx, last_pos=last_pos)

        in_body = in_tail = None
        if self.mesh is not None:
            psh = self.param_shardings(params_abstract)
            in_body = (psh, self._sd(pctx, "batch", "seq"))
            in_tail = (psh, self._sd(pctx, "batch", "seq", "embed"), None)
        body = self._program(key, body_fn, in_shardings=in_body, phase="prefill")
        tail = self._program(key + ":tail", tail_fn, in_shardings=in_tail, phase="prefill")
        return body, tail

    def prefill_split_programs(self, params_abstract, batch: int, seq: int) -> Tuple[PhaseProgram, PhaseProgram]:
        """(body, tail): the overlap split at the last layer's attention."""
        cfg, pctx = self.cfg, self.prefill_ctx
        assert cfg.family == "transformer", "overlap split implemented for the transformer family"
        from repro.models import transformer as T

        def body_fn(params, tokens):
            return T.forward_prefill(params, tokens, cfg, pctx, split_tail=True)

        def tail_fn(params, x_mid):
            return T.prefill_tail(params, x_mid, cfg, pctx)

        in_body = in_tail = None
        if self.mesh is not None:
            psh = self.param_shardings(params_abstract)
            in_body = (psh, self._sd(pctx, "batch", "seq"))
            in_tail = (psh, self._sd(pctx, "batch", "seq", "embed"))
        body = self._program(f"prefill_body:{batch}x{seq}", body_fn,
                             in_shardings=in_body, phase="prefill")
        tail = self._program(f"prefill_tail:{batch}x{seq}", tail_fn,
                             in_shardings=in_tail, phase="prefill")
        return body, tail

    def prefill_chunk_program(
        self, chunk: int, n_slots: int, max_len: int, prefix_width: int
    ) -> PhaseProgram:
        """Chunked prefill against the contiguous decode cache:
        ``fn(params, tokens (1, C), cache, prefix, slot, prefix_len,
        last_pos) -> (logits, new_cache, new_prefix)`` (cache and the fp
        prefix mirror both donated — the chunk installs its KV in place,
        quantize-on-write under ``kv_dtype``).

        This is the bounded-quantum prefill RM: ONE compiled shape per
        chunk size serves every prompt (plus one tail bucket per prompt),
        replacing the per-prompt power-of-two bucket ladder.  The swap is
        fused into the program — each chunk both computes and installs its
        KV, so the fabric can flip back to decode after every quantum.
        ``slot``/``prefix_len``/``last_pos`` are traced scalars: no
        recompilation across slots or chunk indices; ``prefix_width`` is
        compile-time (the runner's geometric ladder over the prefix), so
        short prompts never pay attention over the mirror's full max_len
        capacity.  No pinned in_shardings: the serving core runs these
        unsharded today, and under a mesh GSPMD propagates from the
        committed param/cache buffers (pinning the full tuple like the
        monolithic programs do is future work)."""
        key = f"prefill_chunk:{chunk}+{prefix_width}@{n_slots}x{max_len}"
        if key in self._programs:
            return self._programs[key]
        cfg, pctx = self.cfg, self.prefill_ctx
        assert cfg.family == "transformer", "chunked prefill implemented for the transformer family"
        from repro.models import transformer as T

        def fn(params, tokens, cache, prefix, slot, prefix_len, last_pos):
            return T.prefill_chunk(params, tokens, cache, prefix, slot,
                                   prefix_len, last_pos, cfg, pctx,
                                   prefix_width=prefix_width)

        return self._program(key, fn, donate=(2, 3), phase="prefill")

    def paged_prefill_chunk_program(
        self, chunk: int, max_pages: int, block_size: int, prefix_width: int
    ) -> PhaseProgram:
        """Chunked prefill against the paged pool: ``fn(params, tokens
        (1, C), pages, prefix, page_ids (C/bs,), prefix_len, last_pos) ->
        (logits, new_pages, new_prefix)`` (pool and fp prefix mirror both
        donated).  ``C`` must be a multiple of ``block_size``; the chunk's
        pages are written by the same quantize-on-write scatter the
        monolithic page-write swap uses, with prefix-cache-hit pages
        skipped via out-of-bounds ids.  ``prefix_width`` / sharding: see
        ``prefill_chunk_program`` (unsharded today; GSPMD propagates)."""
        key = f"prefill_chunk_paged:{chunk}+{prefix_width}@{max_pages}x{block_size}"
        if key in self._programs:
            return self._programs[key]
        cfg, pctx = self.cfg, self.prefill_ctx
        assert cfg.family == "transformer", "chunked prefill implemented for the transformer family"
        assert chunk % block_size == 0, (chunk, block_size)
        from repro.models import transformer as T

        def fn(params, tokens, pages, prefix, page_ids, prefix_len, last_pos):
            return T.prefill_chunk_paged(params, tokens, pages, prefix,
                                         page_ids, prefix_len, last_pos, cfg,
                                         pctx, prefix_width=prefix_width)

        return self._program(key, fn, donate=(2, 3), phase="prefill")

    def prefill_chunk_kv_program(self, chunk: int, prefix_width: int) -> PhaseProgram:
        """Compute-only chunked prefill — the disaggregated prefill pool's
        chunk RM: ``fn(params, tokens (1, C), prefix, prefix_len, last_pos)
        -> (logits, chunk_kv, new_prefix)`` (fp prefix mirror donated).
        Same body and logits epilogue as the fused chunk programs; the
        chunk's fp KV is returned for the handoff channel to ship, and the
        decode pool installs it with the SAME quantize-on-write scatter the
        colocated engine fuses in (``chunk_write_program`` /
        ``page_write_program``) — the install split that keeps the two-pool
        engine bit-identical.  No pinned in_shardings, matching the fused
        chunk programs (GSPMD propagates from the committed params)."""
        key = f"prefill_chunk_kv:{chunk}+{prefix_width}"
        if key in self._programs:
            return self._programs[key]
        cfg, pctx = self.cfg, self.prefill_ctx
        assert cfg.family == "transformer", "chunked prefill implemented for the transformer family"
        from repro.models import transformer as T

        def fn(params, tokens, prefix, prefix_len, last_pos):
            return T.prefill_chunk_kv(params, tokens, prefix, prefix_len,
                                      last_pos, cfg, pctx,
                                      prefix_width=prefix_width)

        return self._program(key, fn, donate=(2,), phase="prefill")

    def chunk_write_program(self, chunk: int) -> PhaseProgram:
        """Decode-side install of one shipped prefill chunk into the
        CONTIGUOUS cache: ``fn(cache, kv, slot, prefix_len) -> new_cache``
        (cache donated).  The exact ``write_chunk_kv_q`` scatter
        (quantize-on-write under ``kv_dtype``) the fused
        ``prefill_chunk_program`` runs — split out so the disaggregated
        decode pool installs handoff chunks with the colocated engine's
        bytes.  The paged counterpart is ``page_write_program``."""
        key = f"chunk_write:{chunk}"
        if key in self._programs:
            return self._programs[key]
        from repro.layers.attention import KVCache, write_chunk_kv_q

        def fn(cache, kv, slot, prefix_len):
            return KVCache(
                write_chunk_kv_q(cache.k, kv.k, slot, prefix_len),
                write_chunk_kv_q(cache.v, kv.v, slot, prefix_len),
            )

        return self._program(key, fn, donate=(0,), phase="swap")

    def relayout_program(self, batch: int, seq: int, max_len: int) -> PhaseProgram:
        """The swap: prefill-layout KV -> decode-layout cache buffer.

        Implements (i) the reshard from prefill sharding (batch x heads) to
        decode sharding (batch x *sequence*) — the collective this program
        pays is the TPU bitstream-load analogue; (ii) right-padding into the
        persistent decode buffer; (iii) with ``kv_dtype`` in {"int8",
        "int4"}, quantize-on-write into packed payload + fp32 scale planes
        (halving/quartering decode KV traffic — the subsystem's Eq. (5)
        lever); the legacy ``kv_quant="int8"`` knob keeps its (int8, scale)
        tuple output.
        """
        cfg, pctx = self.cfg, self.decode_ctx
        key = f"relayout:{batch}x{seq}->{max_len}"
        if key in self._programs:
            return self._programs[key]

        def fn(kv):
            def relay(x):  # prefill layout (L, B, Hkv, S, D)
                pad = [(0, 0)] * x.ndim
                pad[-2] = (0, max_len - x.shape[-2])
                y = jnp.pad(x, pad)
                # the layout swap proper: layer-major (prefill writes KV per
                # layer) -> batch-leading decode layout (token-granular
                # in-place appends; see attention.scatter_new_tokens)
                y = jnp.moveaxis(y, 0, 1)
                return pctx.shard(y, "batch", "layers", "kv_heads", "kv_seq", "head_dim")

            kv = jax.tree.map(relay, kv)
            if self.kv_quant == "int8":
                def q(x):
                    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0 + 1e-8
                    return (x / s).astype(jnp.int8), s.astype(jnp.float32)
                return jax.tree.map(q, kv)
            if self.kv_dtype != "fp":
                from repro.quant.kv_quant import quantize_kv_tree

                kv = quantize_kv_tree(kv, self.kv_dtype)
            return kv

        return self._program(key, fn, phase="swap")

    def decode_program(self, params_abstract, batch: int, max_len: int) -> PhaseProgram:
        key = f"decode:{batch}x{max_len}"
        if key in self._programs:
            return self._programs[key]
        cfg, api, pctx = self.cfg, self.api, self.decode_ctx

        def fn(params, token, cache, lengths):
            return api.decode_step(params, token, cache, lengths, cfg, pctx)

        in_sh = None
        if self.mesh is not None:
            psh = self.param_shardings(params_abstract)
            tok_sh = self._sd(pctx, "batch")
            if self.kv_dtype != "fp":
                from repro.models import transformer as T

                cache_abstract = jax.eval_shape(
                    lambda: T.init_cache(cfg, batch, max_len, kv_dtype=self.kv_dtype))
            else:
                cache_abstract = jax.eval_shape(lambda: api.init_cache(cfg, batch, max_len))
            cache_sh = self._cache_shardings(cache_abstract)
            in_sh = (psh, tok_sh, cache_sh, self._sd(pctx, "batch"))
        return self._program(key, fn, in_shardings=in_sh, donate=(2,),
                             phase="decode")

    def paged_decode_program(self, params_abstract, n_slots: int, max_pages: int) -> PhaseProgram:
        """Decode over the paged cache: ``fn(params, token, pages,
        block_tables, lengths) -> (logits, new_pages)``.  The page pool is
        donated (in-place append, like the contiguous decode buffer)."""
        key = f"decode_paged:{n_slots}x{max_pages}"
        if key in self._programs:
            return self._programs[key]
        cfg, pctx = self.cfg, self.decode_ctx
        assert cfg.family == "transformer", "paged decode implemented for the transformer family"
        from repro.models import transformer as T

        def fn(params, token, pages, block_tables, lengths):
            return T.decode_step_paged(params, token, pages, block_tables, lengths, cfg, pctx)

        in_sh = None
        if self.mesh is not None:
            psh = self.param_shardings(params_abstract)
            # Pages shard over heads/head_dim; the page axis stays replicated
            # (any sequence's table may reference any page).
            page_sh = self._sd(pctx, None, "layers", "kv_heads", None, "head_dim")
            from repro.layers.attention import KVCache
            if self.kv_dtype != "fp":
                from repro.quant.kv_quant import QuantKV

                scale_sh = self._sd(pctx, None, "layers", "kv_heads", None)
                leaf_sh = QuantKV(page_sh, scale_sh)
            else:
                leaf_sh = page_sh
            in_sh = (psh, self._sd(pctx, "batch"), KVCache(leaf_sh, leaf_sh), None,
                     self._sd(pctx, "batch"))
        return self._program(key, fn, in_shardings=in_sh, donate=(2,),
                             phase="decode")

    def verify_program(self, params_abstract, batch: int, max_len: int, width: int) -> PhaseProgram:
        """The speculative VERIFY program over the contiguous cache:
        ``fn(params, tokens (B, W), cache, lengths, n_tokens) -> (logits
        (B, W, Vp), new_cache)`` (cache donated, in-place block append).

        A third decode-phase configuration next to ``decode``: the same
        bandwidth-optimized RM dataflow — stream the cache once — but
        scoring ``width = k + 1`` token positions per slot per round, so
        every accepted draft token amortizes the KV/weight stream the
        paper's Eq. (5) says decode is bound by.  One compiled shape per
        (slot batch, width); ``lengths``/``n_tokens`` are traced operands,
        so acceptance-dependent rollback never recompiles."""
        key = f"verify:{batch}x{width}@{max_len}"
        if key in self._programs:
            return self._programs[key]
        cfg, pctx = self.cfg, self.decode_ctx
        assert cfg.family == "transformer", "speculative verify implemented for the transformer family"
        from repro.models import transformer as T

        def fn(params, tokens, cache, lengths, n_tokens):
            return T.verify(params, tokens, cache, lengths, n_tokens, cfg, pctx)

        in_sh = None
        if self.mesh is not None:
            psh = self.param_shardings(params_abstract)
            if self.kv_dtype != "fp":
                cache_abstract = jax.eval_shape(
                    lambda: T.init_cache(cfg, batch, max_len, kv_dtype=self.kv_dtype))
            else:
                cache_abstract = jax.eval_shape(lambda: self.api.init_cache(cfg, batch, max_len))
            in_sh = (psh, self._sd(pctx, "batch", None), self._cache_shardings(cache_abstract),
                     self._sd(pctx, "batch"), self._sd(pctx, "batch"))
        return self._program(key, fn, in_shardings=in_sh, donate=(2,),
                             phase="decode")

    def paged_verify_program(self, params_abstract, n_slots: int, max_pages: int, width: int) -> PhaseProgram:
        """Speculative verify over the paged pool: ``fn(params, tokens
        (B, W), pages, block_tables, lengths, n_tokens) -> (logits
        (B, W, Vp), new_pages)`` (pool donated).  See ``verify_program``;
        pages shard like ``paged_decode_program``."""
        key = f"verify_paged:{n_slots}x{width}@{max_pages}"
        if key in self._programs:
            return self._programs[key]
        cfg, pctx = self.cfg, self.decode_ctx
        assert cfg.family == "transformer", "speculative verify implemented for the transformer family"
        from repro.models import transformer as T

        def fn(params, tokens, pages, block_tables, lengths, n_tokens):
            return T.verify_paged(params, tokens, pages, block_tables, lengths, n_tokens, cfg, pctx)

        in_sh = None
        if self.mesh is not None:
            psh = self.param_shardings(params_abstract)
            page_sh = self._sd(pctx, None, "layers", "kv_heads", None, "head_dim")
            from repro.layers.attention import KVCache
            if self.kv_dtype != "fp":
                from repro.quant.kv_quant import QuantKV

                scale_sh = self._sd(pctx, None, "layers", "kv_heads", None)
                leaf_sh = QuantKV(page_sh, scale_sh)
            else:
                leaf_sh = page_sh
            in_sh = (psh, self._sd(pctx, "batch", None), KVCache(leaf_sh, leaf_sh), None,
                     self._sd(pctx, "batch"), self._sd(pctx, "batch"))
        return self._program(key, fn, in_shardings=in_sh, donate=(2,),
                             phase="decode")

    def block_sampler_program(self, batch: int, width: int) -> PhaseProgram:
        """Vectorized verify-target sampler: ``fn(logits (B, W, V), seeds,
        step0s, temps, top_ks, top_ps) -> (B, W) tokens``.  Block position
        ``i`` of slot ``b`` draws with ``fold_in(PRNGKey(seeds[b]),
        step0s[b] + i)`` — the exact key stream sequential decode uses, so
        the speculative accept rule preserves sampled streams bit-for-bit
        (see ``repro.core.sampling.sample_block_tokens``)."""
        key = f"block_sampler:{batch}x{width}"
        if key in self._programs:
            return self._programs[key]
        from repro.core.sampling import sample_block_tokens

        return self._program(key, sample_block_tokens, phase="sampler")

    def sampler_program(self, batch: int) -> PhaseProgram:
        """Vectorized per-slot token sampler — the decode epilogue program:
        ``fn(logits, seeds, steps, temps, top_ks, top_ps) -> tokens``.

        One compiled configuration per slot-batch size, like the other phase
        programs; it runs after the decode step's logits on device, so a
        sampled batch costs one extra dispatch, not a host round-trip per
        slot.  The PRNG key for slot ``i`` is
        ``fold_in(PRNGKey(seeds[i]), steps[i])`` — stateless, which is what
        keeps preemption replay deterministic under sampling."""
        key = f"sampler:{batch}"
        if key in self._programs:
            return self._programs[key]
        from repro.core.sampling import sample_tokens

        # No pinned in_shardings: the logits arrive however the decode
        # program's epilogue left them (vocab over the model axis under tp;
        # replicated otherwise), and a size-1 batch (the prefill first-token
        # path) cannot be partitioned anyway — GSPMD propagates from the
        # operands for this tiny program.
        return self._program(key, sample_tokens, phase="sampler")

    def page_write_program(self, seq: int, block_size: int) -> PhaseProgram:
        """The paged swap: scatter prefill-layout KV into allocated pages —
        ``fn(pages, kv, page_ids) -> new_pages`` (pages donated).  Plays the
        role ``relayout_program`` plays for the contiguous cache; its
        dispatch is what the latency-overlapped swap hides behind the
        prefill tail.  Under ``kv_dtype`` in {"int8", "int4"} the scatter is
        quantize-on-write: the fp prefill KV is packed (payload + scale
        planes) on its way into the pool and never stored at full width."""
        key = f"page_write:{seq}@{block_size}"
        if key in self._programs:
            return self._programs[key]
        from repro.layers.attention import KVCache, write_prefill_pages_q

        def fn(pages, kv, page_ids):
            return KVCache(
                write_prefill_pages_q(pages.k, kv.k, page_ids, block_size=block_size),
                write_prefill_pages_q(pages.v, kv.v, page_ids, block_size=block_size),
            )

        return self._program(key, fn, donate=(0,), phase="swap")

    def _cache_shardings(self, cache_abstract):
        """Decode-layout cache shardings: KV sequence over the model axis,
        recurrent/SSM states over channels."""
        pctx = self.decode_ctx

        from repro.layers.sharding import sanitize_named_sharding

        def rule(path, leaf):
            ns = _raw_rule(path, leaf)
            return sanitize_named_sharding(ns, leaf.shape) if ns is not None else None

        def _raw_rule(path, leaf):
            nd = leaf.ndim
            p = path.lower()
            if "mlstm" in p:  # (G, nm, B, H, dk[, dv])
                names = [None] * nd
                if nd >= 3:
                    names[2] = "batch"
                if nd >= 5:
                    names[-1] = "state"  # matrix memory dv over tp (long ctx)
                return self._sd(pctx, *names)
            if "slstm" in p:  # (G, B, H, hd)
                return self._sd(pctx, None, "batch", None, "state")
            if "scale" in p and nd == 4:  # (B, L, Hkv, S) quantized-KV scale plane
                return self._sd(pctx, "batch", "layers", "kv_heads", "kv_seq")
            if nd == 5:  # (B, L, Hkv, S, D) KV — decode layout, batch-leading
                return self._sd(pctx, "batch", "layers", "kv_heads", "kv_seq", "head_dim")
            if "conv" in p and nd == 4:  # (L, B, w-1, d_in)
                return self._sd(pctx, "layers", "batch", None, "state")
            if nd == 4:  # (L, B, d_in, N) hymba ssm state
                return self._sd(pctx, "layers", "batch", "state", None)
            if nd == 3:  # (L, B, conv) hymba conv state etc.
                return self._sd(pctx, "layers", "batch", None)
            return self._sd(pctx, *([None] * nd)) if nd else None

        from repro.common.tree import tree_map_with_path_names

        return tree_map_with_path_names(rule, cache_abstract)


def static_engine_decode_rules():
    """The static-accelerator baseline (TeLLMe-style): decode runs with the
    *prefill* configuration — no relayout, KV stays in prefill sharding, the
    decode program is compiled with the compromise layout.  Used by the
    fig6 benchmark to reproduce the paper's PD-Swap-vs-static comparison."""
    return PREFILL_RULES
