"""Spatial prefill/decode disaggregation across pods (beyond-paper mode).

The paper time-multiplexes ONE fabric between phases because an edge FPGA is
a single device.  At pod scale the same asymmetry argument supports *spatial*
disaggregation: dedicate pod 0 to prefill (compute-heavy program resident)
and pod 1 to decode (bandwidth-heavy program resident); the "bitstream load"
becomes a KV transfer over DCN.  Both modes share the PhaseEngine programs —
only meshes and the transfer differ.

This module provides the mesh split, the KV-transfer program (a device_put /
resharding across the pod axis — XLA emits the DCN collective), and the
analytic cost model the fig6/disagg benchmark uses to compare temporal vs
spatial modes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.hardware import DEFAULT_CHIP, ChipSpec
from repro.configs.base import ModelConfig


def split_pod_meshes(mesh: Mesh) -> Tuple[Mesh, Mesh]:
    """(prefill_mesh, decode_mesh) from a (pod, data, model) mesh."""
    assert "pod" in mesh.axis_names, "spatial disaggregation needs a pod axis"
    devs = mesh.devices  # (pods, data, model)
    assert devs.shape[0] >= 2, "need >= 2 pods"
    axes = mesh.axis_names[1:]
    return Mesh(devs[0], axes), Mesh(devs[1], axes)


def kv_transfer_program(decode_mesh: Mesh, spec: P):
    """Program moving prefill-pod KV into the decode pod's sharding."""
    sharding = NamedSharding(decode_mesh, spec)

    def transfer(kv):
        return jax.tree.map(lambda x: jax.device_put(x, sharding), kv)

    return transfer


@dataclasses.dataclass
class DisaggCostModel:
    """Analytic comparison of temporal swap vs spatial disaggregation."""

    cfg: ModelConfig
    chips_per_pod: int
    chip: ChipSpec = DEFAULT_CHIP
    # storage precision of the serving KV cache ("fp" | "int8" | "int4"):
    # a quantized cache shrinks the temporal relayout and the spatial DCN
    # transfer alike (payload + scale planes both move), so the mode
    # comparison must price the same bytes the engine actually ships
    kv_dtype: str = "fp"

    def kv_bytes(self, batch: int, seq: int) -> float:
        c = self.cfg
        if c.attention_free:
            # recurrent state instead of KV
            hd = c.d_model // c.num_heads
            return c.num_layers * batch * c.num_heads * (hd * hd + hd) * 4
        from repro.core.roofline import kv_bytes_per_ctx_token

        return kv_bytes_per_ctx_token(c, self.kv_dtype) * batch * seq

    def temporal_swap_latency(self, batch: int, seq: int) -> float:
        """KV relayout: one read + one write of the cache over HBM, plus the
        resharding all-to-all over ICI (heads->sequence resharding moves each
        byte once)."""
        b = self.kv_bytes(batch, seq) / self.chips_per_pod
        t_hbm = 2 * b / self.chip.hbm_bw
        t_ici = b / (self.chip.ici_bw_per_link * self.chip.ici_links)
        return max(t_hbm, t_ici)

    def spatial_transfer_latency(self, batch: int, seq: int) -> float:
        """Cross-pod KV move over DCN (per-chip share, all NICs in parallel)."""
        b = self.kv_bytes(batch, seq) / self.chips_per_pod
        return b / self.chip.dcn_bw

    def better_mode(self, batch: int, seq: int, decode_steps: int) -> str:
        """Spatial wins when prefill/decode can pipeline across requests and
        the DCN transfer hides under a decode batch; temporal wins for single
        bursty requests (the paper's edge scenario)."""
        t_sp = self.spatial_transfer_latency(batch, seq)
        t_tm = self.temporal_swap_latency(batch, seq)
        return "spatial" if t_sp < t_tm * 4 and decode_steps > 64 else "temporal"
