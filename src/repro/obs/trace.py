"""Process-wide tracer: bounded ring-buffer spans + Chrome trace export.

The serving stack is instrumented at every phase boundary the paper's
timeline argument cares about — request lifecycle events (submit → admit →
prefill chunk[i] → KV handoff → decode round → spec verify → preempt /
replay → shed / abort / finish) and engine spans (swap, chunk compute,
decode quantum, handoff transfer).  Instrumentation sites call the module
singleton ``TRACER``; when tracing is disabled every call is a single
attribute check (hot paths guard with ``if TRACER.enabled`` so the disabled
cost is one branch, CI-gated < 3 % on the decode loop by
``benchmarks/tracing_overhead.py``).

Events land in a ``deque(maxlen=capacity)`` — a long serving run can trace
forever and keep only the most recent window; ``dropped`` counts evictions.
``export_chrome_trace()`` emits the Chrome trace-event JSON format
(chrome://tracing / Perfetto): complete events (``ph: "X"``) and instants
(``ph: "i"``), one lane (``tid``) per *origin* — by default the emitting
thread's name, so the engine step loop, the ``prefill-pool`` dispatch
thread, and explicit lanes like ``kv-handoff`` render as separate tracks
whose overlap is the paper's Fig. 5 as a real trace.

Exactly-once finish: ``finish()`` is the single funnel for terminal
lifecycle events.  While tracing is enabled it asserts no request finishes
twice — the double-stamp class of bug (``done_t`` restamped on a second
finish path) becomes a hard error instead of silently skewed latency.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional


class _NullSpan:
    """No-op context manager returned by ``span()`` when tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tr", "_name", "_lane", "_args", "_t0")

    def __init__(self, tr: "Tracer", name: str, lane: Optional[str], args):
        self._tr = tr
        self._name = name
        self._lane = lane
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tr.complete(self._name, self._t0, time.perf_counter(),
                          lane=self._lane, **(self._args or {}))
        return False


class Tracer:
    """Bounded-ring-buffer event recorder with Chrome trace export.

    Storage is a tuple per event — ``("X", name, t0, dur, lane, args)`` for
    spans, ``("i", name, t, lane, args)`` for instants — appended to a
    ``deque(maxlen=...)``; ``deque.append`` is atomic under the GIL, so the
    engine thread, the prefill-pool thread, and benchmark drivers record
    concurrently without a lock on the hot path.
    """

    DEFAULT_CAPACITY = 65536

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.enabled = False
        self._lock = threading.Lock()
        self._configure(capacity)

    # analysis: allow(lock:unguarded) — callers hold self._lock (enable/clear);
    # __init__ calls it on a not-yet-shared object
    def _configure(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError("tracer capacity must be >= 1")
        self.capacity = capacity  # guarded-by: self._lock
        self._events: deque = deque(maxlen=capacity)  # guarded-by: self._lock
        self._emitted = 0  # guarded-by: self._lock
        self._finished: set = set()  # guarded-by: self._lock
        self._t0 = time.perf_counter()  # guarded-by: self._lock

    # ------------------------------------------------------------ control --

    def enable(self, capacity: Optional[int] = None) -> None:
        """Start recording (fresh buffer).  ``capacity`` bounds the ring."""
        with self._lock:
            self._configure(capacity or self.capacity)
            self.enabled = True

    def disable(self) -> None:
        """Stop recording; the buffered events stay exportable."""
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._configure(self.capacity)

    @property
    # analysis: allow(lock:unguarded) — monitoring read; a torn
    # emitted/len pair can misreport dropped by one scrape, never corrupt
    def dropped(self) -> int:
        """Events evicted by the ring bound (emitted minus retained)."""
        return self._emitted - len(self._events)

    # analysis: allow(lock:unguarded) — list(deque) snapshots atomically
    # under the GIL; used by tests/benchmarks, not the export path
    def events(self) -> List[tuple]:
        return list(self._events)

    # ---------------------------------------------------------- recording --

    # analysis: allow(lock:unguarded) — lock-free hot path by design (class
    # docstring): deque.append and int += are GIL-atomic enough for metering,
    # and a lock here would serialize the engine and pool threads per event
    def complete(self, name: str, t0: float, t1: float,
                 lane: Optional[str] = None, **args) -> None:
        """Record a complete span from ``perf_counter`` stamps the caller
        already took — the hot-path form: sites that time themselves anyway
        (decode round, prefill chunk) pay only this call when enabled and
        one ``if TRACER.enabled`` branch when not."""
        if not self.enabled:
            return
        self._emitted += 1
        self._events.append(
            ("X", name, t0, max(t1 - t0, 0.0),
             lane or threading.current_thread().name, args or None))

    def span(self, name: str, lane: Optional[str] = None, **args):
        """Context-manager span for cold paths."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, lane, args)

    # analysis: allow(lock:unguarded) — lock-free hot path, same contract
    # as complete()
    def instant(self, name: str, lane: Optional[str] = None, **args) -> None:
        if not self.enabled:
            return
        self._emitted += 1
        self._events.append(
            ("i", name, time.perf_counter(),
             lane or threading.current_thread().name, args or None))

    # analysis: allow(lock:unguarded) — _finished is only touched by finish
    # paths, which all run on the engine-step thread (the funnel property
    # this method asserts); set.add is GIL-atomic besides
    def finish(self, request_id: str, reason: Optional[str]) -> None:
        """Terminal lifecycle event — must fire exactly once per request.

        All finish paths (stop/length via ``process_tokens``, resume-at-
        budget, shed, abort) funnel through here; a second finish for the
        same id while tracing is a hard error, catching double-finalize
        bugs that would otherwise only skew ``done_t`` silently."""
        if not self.enabled:
            return
        if request_id in self._finished:
            raise RuntimeError(
                f"duplicate finish event for request {request_id!r} "
                f"(reason={reason!r}): a request must finish exactly once")
        self._finished.add(request_id)
        self.instant("req.finish", request_id=request_id, reason=reason)

    # ------------------------------------------------------------- export --

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON dict (``chrome://tracing`` / Perfetto).

        One ``tid`` per lane in first-seen order, named via ``"M"``
        thread_name metadata; timestamps are microseconds relative to the
        last ``enable()``/``clear()``.
        """
        with self._lock:
            events = list(self._events)
            t0 = self._t0
        lanes: Dict[str, int] = {}

        def tid(lane: str) -> int:
            if lane not in lanes:
                lanes[lane] = len(lanes) + 1
            return lanes[lane]

        out: List[Dict[str, Any]] = []
        for ev in events:
            if ev[0] == "X":
                _, name, ts, dur, lane, args = ev
                rec: Dict[str, Any] = {
                    "name": name, "ph": "X", "pid": 1, "tid": tid(lane),
                    "ts": (ts - t0) * 1e6, "dur": dur * 1e6,
                }
            else:
                _, name, ts, lane, args = ev
                rec = {
                    "name": name, "ph": "i", "s": "t", "pid": 1,
                    "tid": tid(lane), "ts": (ts - t0) * 1e6,
                }
            if args:
                rec["args"] = dict(args)
            out.append(rec)
        meta: List[Dict[str, Any]] = []
        for lane, lane_tid in lanes.items():
            meta.append({"name": "thread_name", "ph": "M", "pid": 1,
                         "tid": lane_tid, "args": {"name": lane}})
            meta.append({"name": "thread_sort_index", "ph": "M", "pid": 1,
                         "tid": lane_tid, "args": {"sort_index": lane_tid}})
        return {"traceEvents": meta + out, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> Dict[str, Any]:
        trace = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return trace


# The process-wide tracer every instrumentation site records into.  A
# single engine per process is the deployment shape (the disagg pools are
# threads of one engine); tests that run several engines call ``clear()``
# between them so the exactly-once finish set does not span runs.
# Rebinding it would silently split the singleton (sites hold direct
# references) — declared shared so repro.analysis flags any rebind.
# analysis: shared-global(TRACER)
TRACER = Tracer()
