"""Engine-facing observability bindings: ONE snapshot builder and ONE
metrics registry over ``EngineCore`` / ``AsyncEngine`` / ``DisaggEngine``.

Before this module each front-end hand-rolled its own ``snapshot()`` —
three near-identical dict builders whose keys could silently drift.  Now:

* ``engine_snapshot(core)`` is the single legacy-shape builder (stats block
  + kv accounting + tenant lanes + roofline drift); engine subclasses add
  sections through ``core.snapshot_sections()`` instead of overriding
  ``snapshot()``, and the async front-end passes its admission counters as
  ``extra`` — every surface goes through the same code path.
* ``engine_registry(core, frontend=None)`` builds a ``MetricsRegistry`` of
  callback views over the live engine: every ``EngineStats`` counter, the
  ``LatencyStat`` windows as quantile summaries, KV accounting, handoff
  counters (disagg), per-tenant lanes and front-end admission (dynamic
  collectors), and the per-phase ``repro_roofline_residency_ratio`` drift
  gauges.  Closures deref ``core.stats`` at collect time, so
  ``reset_stats()`` rebinding is observed automatically.
* ``snapshot_v2(core)`` is the typed structured export of that registry
  (``{"schema": "v2", counters/gauges/histograms}``) — the same numbers
  ``GET /metrics`` serves as Prometheus text.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.obs.drift import PHASES, roofline_drift
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry

# (EngineStats attribute, metric name, help) — the registry's counter view
# of the stats block.  Times are monotonic sums, hence counters.
_STAT_COUNTERS = (
    ("prefill_tokens", "repro_prefill_tokens_total",
     "Prompt tokens prefilled (offered load; restarts excluded)"),
    ("decode_tokens", "repro_decode_tokens_total",
     "Tokens produced by decode/verify rounds"),
    ("decode_rounds", "repro_decode_rounds_total", "Decode quanta executed"),
    ("swaps", "repro_swaps_total", "Logical prefill->decode swaps (one per request)"),
    ("prefill_bursts", "repro_prefill_bursts_total",
     "Prefill phases entered (fabric flips)"),
    ("prefill_chunks", "repro_prefill_chunks_total",
     "Chunked-prefill quanta executed"),
    ("prefix_hits", "repro_prefix_hits_total", "Prompt pages served from the prefix cache"),
    ("prefix_misses", "repro_prefix_misses_total", "Full prompt pages written"),
    ("prefix_hit_tokens", "repro_prefix_hit_tokens_total",
     "Tokens covered by prefix-cache hits"),
    ("preemptions", "repro_preemptions_total", "Requests evicted under pool pressure"),
    ("admission_blocks", "repro_admission_blocks_total",
     "Admissions deferred on pool pressure"),
    ("replayed_tokens", "repro_replayed_tokens_total",
     "Recompute overhead tokens from preemption restarts"),
    ("draft_tokens", "repro_spec_draft_tokens_total", "Draft tokens proposed to verify"),
    ("accepted_tokens", "repro_spec_accepted_tokens_total",
     "Draft tokens the verify pass confirmed"),
    ("verify_rounds", "repro_spec_verify_rounds_total",
     "Decode rounds run through the verify program"),
    ("slot_rounds", "repro_slot_rounds_total",
     "Sum over decode rounds of active slots"),
    ("aborts", "repro_aborts_total", "Requests cancelled mid-flight or queued"),
    ("sheds", "repro_sheds_total", "Queue heads dropped by SLO admission control"),
    ("decode_ctx_tokens", "repro_decode_ctx_tokens_total",
     "Context tokens streamed per decode pass, summed over slot-rounds"),
    ("t_prefill", "repro_prefill_seconds_total", "Wall time in prefill compute"),
    ("t_decode", "repro_decode_seconds_total", "Wall time in decode/verify rounds"),
    ("t_replay", "repro_replay_seconds_total", "Wall time replaying preemption restarts"),
)

_LATENCY_HISTOGRAMS = (
    ("queue_wait", "repro_queue_wait_seconds",
     "Arrival to first successful admission"),
    ("ttft", "repro_ttft_seconds", "Arrival to first emitted token"),
    ("itl", "repro_itl_seconds", "Gap between consecutive streamed deltas"),
)

_HANDOFF_COUNTERS = (
    ("segments", "repro_handoff_segments_total", "KV segments shipped cross-pool"),
    ("eager_segments", "repro_handoff_eager_segments_total",
     "Chunks shipped before their prompt finished"),
    ("bytes_shipped", "repro_handoff_bytes_total", "KV bytes shipped cross-pool"),
    ("installs", "repro_handoff_installs_total", "Deferred installs executed"),
    ("discarded", "repro_handoff_discarded_total",
     "Queued installs dropped on preemption/abort"),
    ("t_dispatch", "repro_handoff_dispatch_seconds_total",
     "Host-visible transfer dispatch time"),
)


def engine_snapshot(core, extra: Optional[Dict[str, Any]] = None) -> dict:
    """The one legacy-shape stats block every surface reports: raw counters
    + derived rates (``EngineStats.snapshot()``), KV accounting, per-tenant
    fair-queue view, roofline drift, subclass sections
    (``core.snapshot_sections()``), and any front-end ``extra``."""
    from repro.serving.slo import LatencyStat

    snap = core.stats.snapshot()
    snap["kv_bytes"] = core.kv_bytes()
    depths = core.scheduler.queue.lane_depths()
    waits = core.stats.tenant_queue_wait
    snap["tenants"] = {
        t: {"queued": depths.get(t, 0),
            "queue_wait_s": waits[t].snapshot() if t in waits
            else LatencyStat().snapshot()}
        for t in sorted(set(depths) | set(waits))
    }
    snap["roofline_drift"] = roofline_drift(core)
    sections = getattr(core, "snapshot_sections", None)
    if sections is not None:
        snap.update(sections())
    if extra:
        snap.update(extra)
    return snap


def engine_registry(core, frontend=None) -> MetricsRegistry:
    """Build the typed registry over one engine (and optionally its async
    front-end).  Every metric is a callback view — the registry never
    copies state, so building it once per server and collecting per scrape
    always reads current values, across ``reset_stats()`` included."""
    reg = MetricsRegistry()
    for attr, name, help_ in _STAT_COUNTERS:
        reg.counter(name, help_,
                    fn=lambda a=attr: float(getattr(core.stats, a)))

    reg.gauge("repro_decode_tput_tokens_per_s",
              "Decode throughput (decode_tokens / t_decode)",
              fn=lambda: core.stats.decode_tput())
    reg.gauge("repro_decode_round_cost_seconds",
              "Mean wall time of one decode round",
              fn=lambda: core.stats.decode_round_cost())
    reg.gauge("repro_spec_acceptance_rate",
              "Fraction of proposed draft tokens accepted",
              fn=lambda: core.stats.acceptance_rate())
    reg.gauge("repro_spec_tokens_per_round",
              "Mean tokens emitted per slot per decode round",
              fn=lambda: core.stats.tokens_per_round())
    reg.gauge("repro_swap_exposed_cost_seconds",
              "Mean decode-visible swap latency",
              fn=lambda: core.stats.swap_agg.mean_cost)
    reg.gauge("repro_swap_hidden_fraction",
              "Mean fraction of swap latency hidden under the prefill tail",
              fn=lambda: core.stats.swap_agg.mean_hidden_fraction)
    for kind in ("allocated", "peak_in_use", "payload"):
        reg.gauge("repro_kv_cache_bytes", "KV cache memory accounting",
                  labels={"kind": kind},
                  fn=lambda k=kind: float(core.kv_bytes()[k]))
    reg.gauge("repro_queue_depth", "Requests in the scheduler wait queue",
              fn=lambda: float(len(core.scheduler.queue)))
    reg.gauge("repro_active_slots", "Slots currently decoding",
              fn=lambda: float(len(core.scheduler.inflight)))
    reg.gauge("repro_prefilling_slots", "Slots mid-(chunked-)prefill",
              fn=lambda: float(len(core._prefilling)))

    for attr, name, help_ in _LATENCY_HISTOGRAMS:
        reg.histogram(name, help_,
                      source_fn=lambda a=attr: getattr(core.stats, a))

    for phase in PHASES:
        reg.gauge(
            "repro_roofline_residency_ratio",
            "Analytic roofline bound / measured seconds-per-token, per phase "
            "(1.0 = running at the bound; falling = efficiency drift)",
            labels={"phase": phase},
            fn=lambda p=phase: float(
                roofline_drift(core).get(p, {}).get("residency_ratio", 0.0)))

    handoff = getattr(core, "handoff", None)
    if handoff is not None:
        for attr, name, help_ in _HANDOFF_COUNTERS:
            reg.counter(name, help_,
                        fn=lambda a=attr: float(getattr(handoff, a)))
        reg.gauge("repro_handoff_pending_installs",
                  "Shipped segments awaiting decode-side install",
                  fn=lambda: float(handoff.pending))

    def tenant_metrics():
        depths = core.scheduler.queue.lane_depths()
        waits = core.stats.tenant_queue_wait
        out = []
        for t in sorted(set(depths) | set(waits)):
            out.append(Gauge(
                "repro_tenant_queued", "Queued requests per tenant lane",
                labels={"tenant": t},
                fn=lambda d=depths.get(t, 0): float(d)))
            if t in waits:
                out.append(Histogram(
                    "repro_tenant_queue_wait_seconds",
                    "Per-tenant queue wait", labels={"tenant": t},
                    source_fn=lambda w=waits[t]: w))
        return out

    reg.register_collector(tenant_metrics)

    from repro.obs.trace import TRACER

    reg.gauge("repro_trace_enabled", "1 when the tracer is recording",
              fn=lambda: float(TRACER.enabled))
    reg.gauge("repro_trace_buffered_events", "Events in the trace ring buffer",
              fn=lambda: float(len(TRACER.events())))
    reg.counter("repro_trace_dropped_events_total",
                "Events evicted by the trace ring bound",
                fn=lambda: float(TRACER.dropped))

    if frontend is not None:
        reg.counter("repro_frontend_accepted_total",
                    "Requests admitted by the async front-end",
                    fn=lambda: float(frontend.accepted))
        reg.counter("repro_frontend_rejected_total",
                    "Submissions refused (backpressure or invalid)",
                    fn=lambda: float(frontend.rejected))
        reg.gauge("repro_frontend_pending",
                  "Accepted requests not yet drained into the core",
                  fn=lambda: float(len(frontend._pending)))
        reg.gauge("repro_frontend_open_streams", "Live client output streams",
                  fn=lambda: float(len(frontend._streams)))
        reg.gauge("repro_frontend_max_queue", "Backpressure bound",
                  fn=lambda: float(frontend.max_queue))

        def reject_metrics():
            return [
                Counter("repro_frontend_reject_reasons_total",
                        "Rejections by machine-readable reason",
                        labels={"reason": r}, fn=lambda n=n: float(n))
                for r, n in sorted(frontend.reject_reasons.items())
            ]

        reg.register_collector(reject_metrics)
    return reg


def snapshot_v2(core, registry: Optional[MetricsRegistry] = None,
                frontend=None) -> dict:
    """Structured typed export of the registry — the same numbers
    ``/metrics`` serves, as ``{"schema": "v2", counters/gauges/histograms}``."""
    reg = registry if registry is not None else engine_registry(
        core, frontend=frontend)
    out = reg.snapshot()
    out["schema"] = "v2"
    return out
