"""Roofline drift attribution: measured per-phase time vs the analytic
bound, as a queryable metric instead of a one-off report.

The paper's efficiency claims are phrased against rooflines — decode is
KV-bandwidth-bound (Eq. 5), prefill is compute-bound, speculation amortizes
the KV stream across accepted tokens.  ``roofline_drift()`` compares what
the engine MEASURED (``EngineStats`` wall-time sums and the streamed-
context accumulator) against what ``core/roofline.py`` predicts for the
same workload on the target chip, per phase:

* ``prefill`` — measured s/prefill-token vs the 2N-flops compute bound
  (``prefill_compute_time``, N = parameter count of the loaded model);
* ``decode`` — measured s/decoded-token vs the Eq. (5) KV-stream bound at
  the MEAN streamed context (kv_dtype-aware), divided by the measured
  tokens-per-round amortization (1.0 without speculation — so the same
  formula covers plain and speculative rounds);
* ``spec_verify`` — present when verify rounds ran: the same measured
  number vs the ANALYTIC speculative bound
  (``decode_kv_stream_time_speculative`` at the measured acceptance rate)
  — the gap between this and ``decode`` is how much of the predicted
  amortization the draft stream actually delivered.

``residency_ratio = bound / measured`` — the fraction of the roofline the
engine achieves (1.0 = running at the bound; CI's CPU runs sit far below a
v5e bound, which is fine: the metric tracks DRIFT over time, regressions
show as the ratio falling).  All host arithmetic over already-maintained
counters: safe to compute on every snapshot/scrape.
"""
from __future__ import annotations

from typing import Any, Dict

PHASES = ("prefill", "decode", "spec_verify")


def _n_params(runner) -> int:
    """Total parameter count of the loaded model, cached on the runner
    (leaf ``.size`` sums only — no device transfer)."""
    cached = getattr(runner, "_obs_n_params", None)
    if cached is not None:
        return cached
    import jax

    n = int(sum(int(x.size) for x in jax.tree.leaves(runner.params)))
    runner._obs_n_params = n
    return n


def _entry(measured: float, bound: float, **extra) -> Dict[str, Any]:
    from repro.core.roofline import roofline_residency

    out = {
        "measured_s_per_token": measured,
        "bound_s_per_token": bound,
        "residency_ratio": roofline_residency(bound, measured),
    }
    out.update(extra)
    return out


def roofline_drift(core) -> Dict[str, Dict[str, Any]]:
    """Per-phase ``{measured_s_per_token, bound_s_per_token,
    residency_ratio}`` for the engine's accumulated stats (empty phases —
    no tokens yet — are omitted).  Bounds come from the same
    ``core.roofline.predict_phase`` predictions the ``program`` analysis
    pass audits the traced programs against — one source for the numbers
    the gate enforces and the metric reports."""
    from repro.core.roofline import predict_phase

    stats = core.stats
    runner = core.runner
    cfg, kv_dtype = runner.cfg, runner.kv_dtype
    out: Dict[str, Dict[str, Any]] = {}

    if stats.prefill_tokens and stats.t_prefill > 0.0:
        out["prefill"] = _entry(
            stats.t_prefill / stats.prefill_tokens,
            predict_phase("prefill", n_params=_n_params(runner)).t_per_token,
            n_params=_n_params(runner),
        )

    if stats.decode_tokens and stats.t_decode > 0.0:
        # mean context STREAMED per decode pass (each round streams every
        # active slot's cache once; the accumulator sums slot lengths per
        # round, slot_rounds normalizes to one pass)
        ctx = (stats.decode_ctx_tokens / stats.slot_rounds
               if stats.slot_rounds else 0.0)
        measured = stats.t_decode / stats.decode_tokens
        tpr = max(stats.tokens_per_round(), 1.0)
        out["decode"] = _entry(
            measured,
            predict_phase("decode", cfg, context=ctx,
                          kv_dtype=kv_dtype).t_per_token / tpr,
            context_mean=ctx,
            kv_dtype=kv_dtype,
            tokens_per_round=tpr,
        )
        if stats.verify_rounds and runner.spec_decode:
            out["spec_verify"] = _entry(
                measured,
                predict_phase("spec_verify", cfg, context=ctx,
                              k=runner.spec_decode,
                              accept_rate=stats.acceptance_rate(),
                              kv_dtype=kv_dtype).t_per_token,
                context_mean=ctx,
                kv_dtype=kv_dtype,
                accept_rate=stats.acceptance_rate(),
                k=runner.spec_decode,
            )
    return out
