from repro.obs.drift import PHASES, roofline_drift
from repro.obs.engine import engine_registry, engine_snapshot, snapshot_v2
from repro.obs.metrics import (
    PROMETHEUS_CONTENT_TYPE,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import TRACER, Tracer
