"""Typed metrics primitives: counters / gauges / histograms + a registry
that renders Prometheus text exposition and structured snapshots.

Two binding styles:

* **owned** — the metric holds its own state (``inc``/``set``/``observe``),
  for new instrumentation;
* **callback** — the metric reads a value (or a stats object) through a
  closure at collect time, which is how the registry absorbs the existing
  ``EngineStats`` fields and ``LatencyStat`` windows without duplicating
  them: the engine keeps its counters, the registry is a *view*.  Closures
  deref through the engine each collect, so ``reset_stats()`` rebinding the
  stats object is observed automatically.

Threading: owned metric state belongs to the instrumented subsystem (the
``metrics-owner`` role in the lock-discipline annotations — the engine
thread for engine metrics); the scrape side only ever takes GIL-atomic,
staleness-tolerant reads through ``value``/``samples``/``summary``.  The
discipline is machine-checked by ``repro.analysis`` (pass ``lock``).

Histograms render in Prometheus *summary* form (quantile labels + _sum +
_count): the serving latencies already live in bounded percentile windows
(``LatencyStat``), and quantiles-over-a-window is the honest export of that
structure — fixed buckets would fabricate resolution the window doesn't
keep.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

PROMETHEUS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

QUANTILES = (0.5, 0.9, 0.95, 0.99)


def _fmt(v: float) -> str:
    """Prometheus sample value: shortest float repr (ints stay ints)."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape_label(v) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _render_labels(labels: Optional[Dict[str, str]]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{_escape_label(v)}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class Counter:
    """Monotonic counter; ``fn`` makes it a live view of an external value."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None,
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.help = help
        self.labels = dict(labels) if labels else None
        self._fn = fn
        self._value = 0.0  # owned-by: metrics-owner

    def inc(self, n: float = 1.0) -> None:  # thread: metrics-owner
        if self._fn is not None:
            raise TypeError(f"counter {self.name} is a callback view")
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self._value += n

    @property
    # analysis: allow(lock:thread) — scrape-side read: a float load is
    # GIL-atomic and scrapes tolerate one-sample staleness
    def value(self) -> float:
        return float(self._fn()) if self._fn is not None else self._value

    def samples(self) -> List[Tuple[str, Optional[Dict[str, str]], float]]:
        return [(self.name, self.labels, self.value)]


class Gauge:
    """Point-in-time value; ``fn`` makes it a live view."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None,
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.help = help
        self.labels = dict(labels) if labels else None
        self._fn = fn
        self._value = 0.0  # owned-by: metrics-owner

    def set(self, v: float) -> None:  # thread: metrics-owner
        if self._fn is not None:
            raise TypeError(f"gauge {self.name} is a callback view")
        self._value = float(v)

    @property
    # analysis: allow(lock:thread) — scrape-side read: a float load is
    # GIL-atomic and scrapes tolerate one-sample staleness
    def value(self) -> float:
        return float(self._fn()) if self._fn is not None else self._value

    def samples(self) -> List[Tuple[str, Optional[Dict[str, str]], float]]:
        return [(self.name, self.labels, self.value)]


class _WindowStat:
    """Owned histogram state: count/sum forever, bounded percentile window
    (the ``LatencyStat`` shape, kept import-free so obs stays a leaf)."""

    def __init__(self, window: int):
        self.count = 0  # owned-by: metrics-owner
        self.total = 0.0  # owned-by: metrics-owner
        self._win: deque = deque(maxlen=window)  # owned-by: metrics-owner

    def record(self, v: float) -> None:  # thread: metrics-owner
        self.count += 1
        self.total += float(v)
        self._win.append(float(v))

    # analysis: allow(lock:thread) — scrape-side read: np.asarray(deque)
    # snapshots under the GIL; quantiles tolerate window staleness
    def percentile(self, q: float) -> float:
        if not self._win:
            return 0.0
        return float(np.percentile(np.asarray(self._win), q))


class Histogram:
    """Quantile summary over a bounded sample window.

    ``source_fn`` binds it to an external stats object (anything with
    ``count``, ``total`` and ``percentile(q)`` — e.g. ``LatencyStat``),
    re-resolved at every collect so stats-object rebinds are seen.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None,
                 window: int = 2048,
                 source_fn: Optional[Callable[[], Any]] = None):
        self.name = name
        self.help = help
        self.labels = dict(labels) if labels else None
        self._source_fn = source_fn
        self._own = None if source_fn is not None else _WindowStat(window)

    def _src(self):
        return self._source_fn() if self._source_fn is not None else self._own

    def observe(self, v: float) -> None:
        if self._own is None:
            raise TypeError(f"histogram {self.name} is a callback view")
        self._own.record(v)

    def summary(self) -> Dict[str, float]:
        src = self._src()
        out = {"count": float(src.count), "sum": float(src.total)}
        out["mean"] = out["sum"] / out["count"] if out["count"] else 0.0
        for q in QUANTILES:
            out[f"p{int(q * 100)}"] = float(src.percentile(q * 100))
        return out

    def samples(self) -> List[Tuple[str, Optional[Dict[str, str]], float]]:
        src = self._src()
        base = dict(self.labels) if self.labels else {}
        rows: List[Tuple[str, Optional[Dict[str, str]], float]] = []
        for q in QUANTILES:
            rows.append((self.name, {**base, "quantile": str(q)},
                         float(src.percentile(q * 100))))
        rows.append((self.name + "_sum", base or None, float(src.total)))
        rows.append((self.name + "_count", base or None, float(src.count)))
        return rows


class MetricsRegistry:
    """Ordered collection of metrics; one schema over every subsystem.

    Several metric objects may share a name (differing by labels — e.g.
    per-tenant counters); they render under one HELP/TYPE block.
    """

    def __init__(self):
        self._metrics: List[Any] = []
        self._collectors: List[Callable[[], List[Any]]] = []

    def register(self, metric) -> Any:
        self._metrics.append(metric)
        return metric

    def register_collector(self, fn: Callable[[], List[Any]]) -> None:
        """A callable producing metrics at collect time — for label sets
        that only exist dynamically (per-tenant lanes, reject reasons)."""
        self._collectors.append(fn)

    def counter(self, name: str, help: str = "", **kw) -> Counter:
        return self.register(Counter(name, help, **kw))

    def gauge(self, name: str, help: str = "", **kw) -> Gauge:
        return self.register(Gauge(name, help, **kw))

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        return self.register(Histogram(name, help, **kw))

    def metrics(self) -> List[Any]:
        out = list(self._metrics)
        for fn in self._collectors:
            out.extend(fn())
        return out

    # ------------------------------------------------------------- export --

    def prometheus_text(self) -> str:
        """Prometheus text exposition format 0.0.4.

        Histograms render as the ``summary`` type (quantile labels): the
        underlying windows keep samples, not fixed buckets.
        """
        lines: List[str] = []
        seen_header: set = set()
        for m in self.metrics():  # registered + collector-produced
            if m.name not in seen_header:
                seen_header.add(m.name)
                if m.help:
                    lines.append(f"# HELP {m.name} {m.help}")
                ptype = "summary" if m.kind == "histogram" else m.kind
                lines.append(f"# TYPE {m.name} {ptype}")
            for name, labels, value in m.samples():
                lines.append(f"{name}{_render_labels(labels)} {_fmt(value)}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict[str, Any]:
        """Structured dump: ``{counters: {...}, gauges: {...},
        histograms: {...}}``; labeled series nest under their label sets."""
        out: Dict[str, Dict[str, Any]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        for m in self.metrics():  # registered + collector-produced
            section = out[m.kind + "s"]
            value = m.summary() if m.kind == "histogram" else m.value
            if m.labels:
                key = ",".join(f"{k}={v}" for k, v in sorted(m.labels.items()))
                section.setdefault(m.name, {})[key] = value
            else:
                section[m.name] = value
        return out
