"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else must see the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1):
    """Small mesh over whatever devices this host actually has (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))
