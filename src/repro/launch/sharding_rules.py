"""Per-parameter PartitionSpecs inferred from pytree paths.

Training: FSDP (big dim over the data axis) x TP (heads/ffn/vocab over the
model axis).  Inference: TP only (fsdp=None) so decode never all-gathers
weights.  MoE experts shard over the model axis when EP applies.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.tree import tree_map_with_path_names
from repro.configs.base import ModelConfig

# linears whose *output* dim is tensor-parallel
_TP_OUT = ("wq/w", "wk/w", "wv/w", "w_in/w", "w_gate/w", "w_up/w",
           "w_qkv", "w_if", "w_og", "ssm/w_in", "slstm/w")
# linears whose *input* dim is tensor-parallel (psum after)
_TP_IN = ("wo/w", "w_out/w", "w_down/w", "mlstm/w_out", "slstm/w_out", "ssm/w_out")
# biases that follow a TP-output linear
_TP_BIAS = ("wq/b", "wk/b", "wv/b", "w_in/b", "slstm/b")
# ssm per-channel tensors: channel dim (second-to-last or last) is TP
_SSM_CHANNEL = ("ssm/conv", "a_log", "w_bc", "w_dt", "d_skip", "dt_bias")


def param_pspec(path: str, leaf: Any, *, tp: Optional[str], fsdp: Optional[str], ep: bool) -> P:
    nd = leaf.ndim
    p = path.lower()

    def spec(*tail):
        return P(*((None,) * (nd - len(tail)) + tail))

    if nd == 0:
        return P()
    if p.endswith("emb"):
        return P(tp, fsdp)
    if p.endswith("lm_head"):
        return P(fsdp, tp)
    if "pos_dec" in p:
        return P(*(None,) * nd)
    # MoE expert stacks: (L, E, d, f) / (L, E, f, d)
    if "/moe/" in p or ("moe" in p and nd == 4):
        if "router" in p:
            return spec(fsdp, None)
        if "w_down" in p:
            return spec(tp, None, fsdp) if ep else spec(None, tp, fsdp)
        return spec(tp, fsdp, None) if ep else spec(None, fsdp, tp)
    if any(p.endswith(s) or f"/{s}/" in p + "/" for s in _TP_BIAS):
        return spec(tp)
    if any(s in p for s in _TP_IN):
        return spec(tp, fsdp)
    if any(s in p for s in _TP_OUT):
        return spec(fsdp, tp)
    if "slstm/r" in p:  # (G, H, hd, 4hd)
        return spec(None, tp)
    if any(s in p for s in _SSM_CHANNEL):
        if p.endswith(("d_skip", "dt_bias")):
            return spec(tp)
        if "conv" in p:
            return spec(tp)  # (L, w, d_in): channel is last
        return spec(tp, None)  # (L, d_in, N)-shaped
    if "router" in p:
        return spec(fsdp, None)
    return P(*(None,) * nd)  # norms, gates, stabilizers: replicated


def params_shardings(params: Any, cfg: ModelConfig, mesh: Mesh, *, train: bool,
                     tp_axis: str = "model", fsdp_axis: Optional[str] = "data") -> Any:
    """Pytree of NamedShardings matching ``params``."""
    tp = tp_axis if (tp_axis and tp_axis in mesh.axis_names) else None
    fsdp = fsdp_axis if (train and fsdp_axis and fsdp_axis in mesh.axis_names) else None
    ep = bool(cfg.moe and tp and cfg.num_experts % mesh.shape[tp] == 0)

    def rule(path, leaf):
        from repro.layers.sharding import sanitize_spec

        spec = param_pspec(path, leaf, tp=tp, fsdp=fsdp, ep=ep)
        return NamedSharding(mesh, sanitize_spec(spec, leaf.shape, mesh))

    return tree_map_with_path_names(rule, params)


def eval_shape_params(cfg: ModelConfig, dtype=None):
    """ShapeDtypeStruct pytree of the params without allocating (dry-run)."""
    import jax.numpy as jnp

    from repro.models import get_model

    api = get_model(cfg)
    kw = {} if dtype is None else {"dtype": dtype}
    return jax.eval_shape(lambda k: api.init(cfg, k, **kw), jax.random.PRNGKey(0))
