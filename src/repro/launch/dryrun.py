import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import: jax locks the host
#   platform device count at first init, and the production meshes below need
#   512 placeholder devices (2 pods x 16 x 16).  Only the dry-run does this.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the real phase program (train_step for
train shapes, forward_prefill for prefill shapes, decode_step for decode
shapes), lowers it against ShapeDtypeStruct inputs (no allocation), compiles
it for the production mesh, and records:

  * memory_analysis()   — proves the cell fits per-device HBM
  * cost_analysis()     — FLOPs / bytes for the §Roofline terms
  * collective bytes    — parsed from the optimized HLO
  * the three roofline terms + dominant bottleneck + MODEL_FLOPS ratio

Results go to results/dryrun/<arch>__<shape>__<mesh>.json (incremental:
existing cells are skipped unless --force).

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeCell, applicable_shapes
from repro.core.kernel_substitution import kernel_costs_for_cell
from repro.core.phase_engine import PhaseEngine
from repro.core.roofline import (
    collective_bytes_from_hlo,
    cost_analysis_dict,
    memory_analysis_bytes,
    roofline_from_artifacts,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding_rules import eval_shape_params
from repro.models import get_model
from repro.train.trainer import TrainConfig, jit_train_step
from repro.optim.adamw import adamw_init


def input_specs(arch: str, shape: str, *, multi_pod: bool = False) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    specs: dict = {}
    if cell.kind == "train":
        specs["batch"] = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }
        if cfg.family == "encdec":
            specs["batch"]["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        specs["step"] = jax.ShapeDtypeStruct((), i32)
    elif cell.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    else:  # decode: one new token against a seq_len-deep cache
        specs["token"] = jax.ShapeDtypeStruct((b,), i32)
        specs["lengths"] = jax.ShapeDtypeStruct((b,), i32)
        api = get_model(cfg)
        if api.init_cache is not None and cfg.family != "xlstm":
            specs["cache"] = jax.eval_shape(lambda: api.init_cache(cfg, b, s))
        else:
            specs["cache"] = jax.eval_shape(lambda: api.init_cache(cfg, b))
    return specs


def _model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    n = cfg.active_param_count()
    tokens = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch  # decode: one token per sequence


def _train_microbatches(cfg: ModelConfig) -> int:
    return 2 if cfg.d_model >= 8192 else 1


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Path, *, force: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = out_dir / f"{arch}__{shape}__{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    cell = SHAPES[shape]
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        rec = {
            "arch": arch, "shape": shape, "mesh": mesh_name, "status": "skipped",
            "reason": "pure full-attention arch: 500k dense decode is the quadratic/KV wall "
                      "this cell probes; see DESIGN.md §4",
        }
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    specs = input_specs(arch, shape, multi_pod=multi_pod)
    params_abs = eval_shape_params(cfg, dtype=jnp.bfloat16)
    api = get_model(cfg)

    def lower_variant(variant_cfg: ModelConfig):
        if cell.kind == "train":
            tcfg = TrainConfig(microbatches=_train_microbatches(variant_cfg))
            step_fn = jit_train_step(variant_cfg, tcfg, mesh, params_abs, donate=True)
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            return step_fn.lower(params_abs, opt_abs, specs["batch"], specs["step"])
        long_ctx = cell.name == "long_500k"
        engine = PhaseEngine(variant_cfg, mesh, max_len=cell.seq_len, long_context=long_ctx)
        if cell.kind == "prefill":
            prog = engine.prefill_program(params_abs, cell.global_batch, cell.seq_len,
                                          frames=variant_cfg.family == "encdec")
            args = (params_abs, specs["tokens"]) + ((specs["frames"],) if variant_cfg.family == "encdec" else ())
            return prog.fn.lower(*args)
        prog = engine.decode_program(params_abs, cell.global_batch, cell.seq_len)
        return prog.fn.lower(params_abs, specs["token"], specs["cache"], specs["lengths"])

    def analyze(variant_cfg, *, kernel_sub: bool):
        lowered = lower_variant(variant_cfg)
        compiled = lowered.compile()
        cost = cost_analysis_dict(compiled)
        peak_mem = memory_analysis_bytes(compiled)
        hlo = compiled.as_text()
        kc = None
        if kernel_sub:
            tp = mesh.shape["model"]
            dp = chips // tp
            kc = kernel_costs_for_cell(cfg, cell, dp=dp, tp=tp)
        report = roofline_from_artifacts(
            f"{arch}/{shape}/{mesh_name}", cost, hlo, chips,
            model_flops=_model_flops(cfg, cell), peak_memory=peak_mem,
            kernel_cost=kc,
        )
        try:
            ma = compiled.memory_analysis()
            mem_str = str(ma)
            mem_fields = {
                "args": float(ma.argument_size_in_bytes),
                "temp": float(ma.temp_size_in_bytes),
                "output": float(ma.output_size_in_bytes),
                "alias": float(ma.alias_size_in_bytes),
            }
        except Exception as e:  # pragma: no cover
            mem_str, mem_fields = f"unavailable: {e}", {}
        return report, cost, peak_mem, mem_str, len(hlo), mem_fields

    # Variant 1 — generic XLA attention: the static-baseline program.
    report_xla, cost, peak_mem, mem_str, hlo_bytes, mem_fields = analyze(cfg, kernel_sub=False)
    t_xla = time.time() - t0

    # Variant 2 — kernel-substituted (PD-Swap phase RM / flash-train kernel).
    report_kernel = None
    mem_fields_stub = {}
    if not (cfg.family == "xlstm" and cell.kind == "decode"):
        stub_cfg = dataclasses.replace(cfg, attn_impl="stub")
        report_kernel, _, peak_stub, _, _, mem_fields_stub = analyze(stub_cfg, kernel_sub=True)

    headline = report_kernel or report_xla
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "status": "ok",
        "chips": chips,
        "kind": cell.kind,
        "lower_compile_s": round(t_xla, 2),
        "compile_s": round(t_xla, 2),
        "memory_analysis": mem_str,
        "peak_memory_per_device": peak_mem,
        "cost_analysis": {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))},
        "xla_vs_loop_aware": report_xla.extras.get("xla_cost_analysis", {}),
        "collective_bytes": headline.collective_breakdown,
        # headline roofline: kernel-substituted (PD-Swap) when applicable
        "roofline": headline.row(),
        # the static-generic program's roofline (paper's baseline comparison)
        "roofline_xla_generic": report_xla.row(),
        "kernel_substituted": report_kernel is not None,
        "hlo_bytes": hlo_bytes,
    }
    rec["memory_fields"] = mem_fields
    if report_kernel is not None:
        rec["peak_memory_stub_per_device"] = peak_stub
        rec["kernel_vmem_bytes"] = report_kernel.extras.get("kernel_vmem_bytes")
        rec["memory_fields_stub"] = mem_fields_stub
        # TPU-projected HBM footprint: sharded args (params + cache) + the
        # kernel's VMEM-resident working set; the CPU compile's temp buffers
        # hold bf16-dot upcast copies that do not exist on TPU.
        rec["hbm_footprint_projected"] = (
            mem_fields_stub.get("args", 0.0)
            + float(report_kernel.extras.get("kernel_vmem_bytes") or 0)
        )
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ALL_ARCHS)
    p.add_argument("--shape", choices=list(SHAPES))
    p.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    p.add_argument("--all", action="store_true", help="run the full assigned matrix")
    p.add_argument("--force", action="store_true")
    p.add_argument("--out", default="results/dryrun")
    args = p.parse_args()

    out_dir = Path(args.out)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    cells = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for cell in applicable_shapes(get_config(arch)):
                cells.append((arch, cell.name))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'multi' if mp else 'single'}"
            try:
                rec = run_cell(arch, shape, mp, out_dir, force=args.force)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"[ok]   {tag}: dominant={r['dominant']} "
                          f"t=({r['t_compute']:.2e},{r['t_memory']:.2e},{r['t_collective']:.2e})s "
                          f"compile={rec['compile_s']}s")
                else:
                    print(f"[skip] {tag}: {rec['reason']}")
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {[f[0] for f in failures]}")
    print("dry-run complete.")


if __name__ == "__main__":
    main()
