"""Cluster serving entrypoint: the step-driven engine under a synthetic load.

    python -m repro.launch.serve --arch smollm-135m --reduced \
        --requests 8 --mode pdswap --swap-policy swap-aware \
        --temperature 0.8 --top-k 40 --top-p 0.95

Drives ``EngineCore.step()`` (the paper's single-RP temporal logic swap, or
the static TeLLMe-style baseline with --mode static) with per-request
``SamplingParams`` and a pluggable ``SwapPolicy``, and prints per-phase
stats including the measured overlap of the swap and per-request TTFT /
queue wait.  Requests arrive on a seeded Poisson process
(``--arrival-rate R`` requests/s, via ``repro.serving.arrivals``) or on the
legacy step grid (``--arrival-every N`` submits one request every N steps)
so the swap policy actually has transitions to schedule.

With ``--serve`` the same engine runs behind an HTTP front-end on stdlib
asyncio streams (no web framework): ``POST /generate`` streams each token
delta as a server-sent event, ``GET /stats`` returns the engine snapshot as
JSON (``GET /stats/v2`` the typed registry form), ``GET /metrics`` serves
the Prometheus text exposition, and saturation surfaces as ``429`` with the
admission-reject reason.  ``--trace-out trace.json`` records the run's
lifecycle/engine spans and writes a Chrome trace (chrome://tracing,
https://ui.perfetto.dev) on exit — batch and server modes both.

    python -m repro.launch.serve --arch smollm-135m --reduced --serve --port 8035
    curl -N -d '{"prompt": [3, 1, 4, 1, 5, 9], "max_new": 8}' \
        http://127.0.0.1:8035/generate
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, reduced_config
from repro.models import get_model
from repro.serving import (
    AdmissionRejected,
    AsyncEngine,
    DisaggEngine,
    EngineCore,
    Request,
    SamplingParams,
    make_disagg_meshes,
)
from repro.serving.arrivals import poisson_times
from repro.serving.policy import POLICIES


def _http_payload(writer, status: str, body: bytes,
                  ctype: str = "application/json") -> None:
    writer.write(
        f"HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n"
        f"Content-Length: {len(body)}\r\nConnection: close\r\n\r\n".encode()
        + body)


@dataclasses.dataclass
class ServerState:
    """Shared handler state: once ``draining`` flips, new ``POST /generate``
    submits answer ``503`` while ``GET /stats`` keeps serving, so a load
    balancer sees the instance leave rotation without losing observability."""

    draining: bool = False


async def handle_connection(eng: AsyncEngine, default_params: SamplingParams,
                            reader: asyncio.StreamReader,
                            writer: asyncio.StreamWriter,
                            state: "ServerState | None" = None) -> None:
    """One HTTP exchange on raw asyncio streams (no web framework).

    ``POST /generate`` takes a JSON body — ``prompt`` (token ids, required),
    optional ``max_new``, ``tenant``, ``weight``, ``temperature``, ``top_k``,
    ``top_p``, ``seed``, ``stop_tokens`` — and streams one server-sent event
    per ``RequestOutput`` delta.  A saturated admission queue answers ``429``
    with the reject reason instead of hanging the client.  ``GET /stats``
    returns ``AsyncEngine.snapshot()``.
    """
    try:
        request_line = await reader.readline()
        parts = request_line.decode("latin-1").split()
        if len(parts) < 2:
            return
        method, path = parts[0], parts[1]
        headers = {}
        while True:
            line = await reader.readline()
            if line in (b"\r\n", b"\n", b""):
                break
            key, _, val = line.decode("latin-1").partition(":")
            headers[key.strip().lower()] = val.strip()
        body = b""
        length = int(headers.get("content-length", "0") or 0)
        if length:
            body = await reader.readexactly(length)

        if method == "GET" and path == "/stats":
            _http_payload(writer, "200 OK", json.dumps(eng.snapshot()).encode())
        elif method == "GET" and path == "/stats/v2":
            _http_payload(writer, "200 OK",
                          json.dumps(eng.snapshot_v2()).encode())
        elif method == "GET" and path == "/metrics":
            from repro.obs.metrics import PROMETHEUS_CONTENT_TYPE

            _http_payload(writer, "200 OK",
                          eng.metrics_registry().prometheus_text().encode(),
                          ctype=PROMETHEUS_CONTENT_TYPE)
        elif method == "POST" and path == "/generate":
            if state is not None and state.draining:
                _http_payload(writer, "503 Service Unavailable", json.dumps(
                    {"error": "shutting down: server is draining"}).encode())
                return
            try:
                spec = json.loads(body or b"{}")
                prompt = np.asarray(spec["prompt"], np.int32)
            except (ValueError, KeyError, TypeError) as e:
                _http_payload(writer, "400 Bad Request",
                              json.dumps({"error": f"bad request body: {e}"}).encode())
                return
            sp = default_params
            if any(k in spec for k in
                   ("temperature", "top_k", "top_p", "seed", "stop_tokens")):
                sp = SamplingParams(
                    temperature=float(spec.get("temperature", default_params.temperature)),
                    top_k=int(spec.get("top_k", default_params.top_k)),
                    top_p=float(spec.get("top_p", default_params.top_p)),
                    seed=int(spec.get("seed", default_params.seed or 0)),
                    stop_tokens=tuple(spec.get("stop_tokens",
                                               default_params.stop_tokens)),
                )
            try:
                stream = await eng.submit(
                    prompt, sp,
                    request_id=spec.get("request_id"),
                    max_new=spec.get("max_new"),
                    tenant=str(spec.get("tenant", "default")),
                    weight=float(spec.get("weight", 1.0)),
                )
            except AdmissionRejected as e:
                _http_payload(writer, "429 Too Many Requests",
                              json.dumps({"error": e.reason}).encode())
                return
            writer.write(b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n"
                         b"Cache-Control: no-cache\r\nConnection: close\r\n\r\n")
            await writer.drain()
            async for out in stream:
                event = {
                    "request_id": out.request_id,
                    "new_token_ids": list(out.new_token_ids),
                    "finished": out.finished,
                    "finish_reason": out.finish_reason,
                }
                writer.write(b"data: " + json.dumps(event).encode() + b"\n\n")
                await writer.drain()
        else:
            _http_payload(writer, "404 Not Found",
                          json.dumps({"error": f"no route {method} {path}"}).encode())
    except (ConnectionResetError, asyncio.IncompleteReadError):
        pass  # client went away mid-exchange; the engine keeps its own state
    finally:
        try:
            await writer.drain()
            writer.close()
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass


async def serve_http(core: EngineCore, default_params: SamplingParams,
                     host: str, port: int, *, max_queue: int = 64,
                     ready: "asyncio.Event | None" = None,
                     stop: "asyncio.Event | None" = None,
                     grace_s: float = 5.0) -> int:
    """Run the engine behind the asyncio-streams HTTP front-end until asked
    to stop, then shut down gracefully.

    ``ready`` (tests) is set once the socket is listening.  SIGINT/SIGTERM —
    or ``stop`` being set, the test hook — starts the drain: new
    ``POST /generate`` submits answer ``503`` (``GET /stats`` stays up),
    in-flight streams get up to ``grace_s`` seconds to finish naturally, and
    whatever is still running at the deadline is aborted by the engine
    shutdown with a terminal ``finish_reason="abort"`` delta, so no client
    reader ever hangs on a half-open stream.
    """
    if stop is None:
        stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    state = ServerState()
    hooked = []
    for sig in (signal.SIGINT, signal.SIGTERM):
        try:
            loop.add_signal_handler(sig, stop.set)
            hooked.append(sig)
        except (NotImplementedError, RuntimeError, ValueError):
            pass  # non-main thread or a platform without loop signal support
    try:
        async with AsyncEngine(core, max_queue=max_queue) as eng:
            server = await asyncio.start_server(
                lambda r, w: handle_connection(eng, default_params, r, w,
                                               state=state),
                host, port)
            bound = server.sockets[0].getsockname()
            print(f"serving on http://{bound[0]}:{bound[1]}  "
                  f"(POST /generate streams SSE, GET /stats, GET /metrics)")
            if ready is not None:
                ready.set()
            async with server:
                try:
                    await stop.wait()
                except asyncio.CancelledError:
                    pass
                state.draining = True
                print(f"draining: rejecting new work (503), waiting up to "
                      f"{grace_s:.1f}s for in-flight streams")
                deadline = loop.time() + grace_s
                while loop.time() < deadline and (
                        core.has_unfinished()
                        or eng.snapshot()["frontend"]["open_streams"]):
                    await asyncio.sleep(0.02)
            # AsyncEngine.__aexit__ now aborts anything still unfinished and
            # routes each stream its terminal delta before the loop exits
    finally:
        for sig in hooked:
            loop.remove_signal_handler(sig)
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ALL_ARCHS, default="smollm-135m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--mode", default="pdswap", choices=["pdswap", "static"])
    p.add_argument("--cache-layout", default="contiguous", choices=["contiguous", "paged"])
    p.add_argument("--block-size", type=int, default=16,
                   help="tokens per KV page (paged layout)")
    p.add_argument("--num-blocks", type=int, default=None,
                   help="KV pool pages (paged layout; default = full provisioning)")
    p.add_argument("--kv-dtype", default="fp", choices=["fp", "int8", "int4"],
                   help="KV-cache precision: packed int8/int4 payload + fp32 "
                        "scale planes (fused dequant in the decode kernels)")
    p.add_argument("--prefill-chunk", type=int, default=None,
                   help="tokens per prefill quantum: run long prompts as "
                        "bounded chunks with a decode round between each, "
                        "instead of one atomic burst (None = monolithic; "
                        "paged layout needs a multiple of --block-size)")
    p.add_argument("--spec-decode", type=int, default=0, metavar="K",
                   help="speculative decoding draft depth: each decode round "
                        "drafts up to K tokens by prompt lookup (n-gram match "
                        "against the request's own history) and verifies all "
                        "K+1 positions in one forward pass (0 = off); greedy "
                        "streams stay bit-identical to plain decode")
    p.add_argument("--spec-ngram", type=int, default=3, metavar="N",
                   help="prompt-lookup n-gram size for --spec-decode drafting")
    p.add_argument("--disagg", action="store_true",
                   help="disaggregated serving: prefill and decode run as "
                        "two phase-specialized pools with KV handoff between "
                        "them (uses the first two local devices as 1-wide "
                        "pools when available, else colocates both pools on "
                        "the default device; greedy outputs stay "
                        "bit-identical to the single engine)")
    p.add_argument("--ragged", action="store_true",
                   help="draw prompt lengths uniformly in [4, prompt_len]")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--no-overlap", action="store_true",
                   help="serialize the swap after the prefill tail (ablation)")
    p.add_argument("--seed", type=int, default=0,
                   help="seeds the params, the workload, and sampling")
    # --- step-driven serving API ---
    p.add_argument("--swap-policy", default="drain", choices=sorted(POLICIES),
                   help="prefill<->decode transition policy (paper: drain)")
    p.add_argument("--arrival-every", type=int, default=0,
                   help="submit one request every N steps (0 = all up front; "
                        "ignored when --arrival-rate is set)")
    p.add_argument("--arrival-rate", type=float, default=0.0,
                   help="seeded Poisson arrivals at R requests/s wall clock "
                        "(0 = use --arrival-every)")
    # --- HTTP/SSE server mode ---
    p.add_argument("--serve", action="store_true",
                   help="run as an HTTP server instead of a batch drive: "
                        "POST /generate streams SSE token deltas, GET /stats "
                        "returns the engine snapshot")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8035)
    p.add_argument("--max-queue", type=int, default=64,
                   help="server mode: admission backlog bound before "
                        "submits are rejected with 429")
    p.add_argument("--grace", type=float, default=5.0,
                   help="server mode: seconds to let in-flight streams "
                        "finish after SIGINT/SIGTERM before aborting them")
    p.add_argument("--trace-out", default=None, metavar="PATH",
                   help="record per-request lifecycle + engine spans and "
                        "write a Chrome trace-event JSON here on exit "
                        "(open in chrome://tracing or ui.perfetto.dev)")
    p.add_argument("--temperature", type=float, default=0.0,
                   help="sampling temperature (0 = greedy, the paper setting)")
    p.add_argument("--top-k", type=int, default=0, help="top-k truncation (0 = off)")
    p.add_argument("--top-p", type=float, default=1.0, help="nucleus mass (1.0 = off)")
    p.add_argument("--stop-token", type=int, action="append", default=None,
                   help="token id that ends generation (repeatable)")
    args = p.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    assert cfg.family == "transformer", "serving engine drives the transformer family"
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(args.seed), dtype=jnp.float32)

    kw = dict(n_slots=args.slots, max_len=args.max_len,
              prompt_len=args.prompt_len, mode=args.mode,
              cache_layout=args.cache_layout, block_size=args.block_size,
              num_blocks=args.num_blocks, kv_dtype=args.kv_dtype,
              overlap=not args.no_overlap, swap_policy=args.swap_policy,
              prefill_chunk=args.prefill_chunk,
              spec_decode=args.spec_decode or None,
              spec_ngram=args.spec_ngram)
    if args.disagg:
        try:
            pmesh, dmesh = make_disagg_meshes()
        except ValueError:
            pmesh = dmesh = None
            print("disagg: fewer than 2 local devices, colocating both pools "
                  "(set XLA_FLAGS=--xla_force_host_platform_device_count=2 "
                  "for real two-pool overlap on CPU)")
        eng = DisaggEngine(cfg, params, prefill_mesh=pmesh,
                           decode_mesh=dmesh, **kw)
    else:
        eng = EngineCore(cfg, params, **kw)
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p, seed=args.seed,
                        stop_tokens=tuple(args.stop_token or ()))
    if args.trace_out:
        from repro.obs.trace import TRACER

        TRACER.enable()
    if args.serve:
        try:
            return asyncio.run(serve_http(eng, sp, args.host, args.port,
                                          max_queue=args.max_queue,
                                          grace_s=args.grace))
        except KeyboardInterrupt:
            return 0
        finally:
            if args.trace_out:
                trace = TRACER.export_chrome_trace(args.trace_out)
                print(f"trace: {len(trace['traceEvents'])} events -> "
                      f"{args.trace_out} ({TRACER.dropped} dropped)")

    rng = np.random.default_rng(args.seed)
    ragged_lo = max(1, min(4, args.prompt_len))  # keep low < high for tiny prompt-len
    pending = []
    for i in range(args.requests):
        n = int(rng.integers(ragged_lo, args.prompt_len + 1)) if args.ragged else args.prompt_len
        prompt = rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
        pending.append(Request(f"req-{i}", prompt, max_new=args.max_new, params=sp))

    if args.arrival_rate > 0.0:
        # seeded Poisson arrivals in wall-clock time: submit each request
        # once its sampled arrival instant has passed, sleeping only when
        # the engine is otherwise idle
        times = poisson_times(args.arrival_rate, len(pending),
                              np.random.default_rng(args.seed + 1))
        arrivals = list(zip(times.tolist(), pending))
        pending = []
        t0 = time.perf_counter()
        while eng.has_unfinished() or arrivals:
            now = time.perf_counter() - t0
            while arrivals and arrivals[0][0] <= now:
                eng.submit(arrivals.pop(0)[1])
            if eng.has_unfinished():
                eng.step()
            elif arrivals:
                time.sleep(max(0.0, arrivals[0][0] - (time.perf_counter() - t0)))
    else:
        if args.arrival_every <= 0:
            for r in pending:
                eng.submit(r)
            pending = []
        step = 0
        while eng.has_unfinished() or pending:
            step += 1
            if pending and (step - 1) % args.arrival_every == 0:
                eng.submit(pending.pop(0))
            eng.step()
    stats = eng.stats

    sampled = "greedy" if sp.greedy else (
        f"T={sp.temperature} top_k={sp.top_k} top_p={sp.top_p} seed={sp.seed}")
    print(f"\nmode={args.mode} overlap={not args.no_overlap} "
          f"policy={args.swap_policy} sampling={sampled}")
    print(f"  requests finished : {len(eng.finished)}/{args.requests}")
    print(f"  prefill tokens    : {stats.prefill_tokens}  ({stats.t_prefill:.2f}s)")
    print(f"  decode tokens     : {stats.decode_tokens}  ({stats.t_decode:.2f}s, "
          f"{stats.decode_tput():.1f} tok/s on this host)")
    print(f"  logic swaps       : {stats.swaps}  in {stats.prefill_bursts} "
          f"prefill bursts (fabric flips)")
    if stats.prefill_chunks:
        print(f"  prefill chunks    : {stats.prefill_chunks}  "
              f"(chunk={args.prefill_chunk} tokens, decode interleaved between chunks)")
    if stats.verify_rounds:
        print(f"  speculative decode: k={args.spec_decode} ngram={args.spec_ngram}  "
              f"{stats.accepted_tokens}/{stats.draft_tokens} drafts accepted "
              f"({100*stats.acceptance_rate():.0f}%), "
              f"{stats.tokens_per_round():.2f} tokens/round over "
              f"{stats.verify_rounds} verify rounds")
    # client-visible TTFT: arrival (submit) to first token, queueing included
    ttfts = [r.first_token_t - r.arrival_time_s
             for r in eng.finished.values() if r.first_token_t]
    if ttfts:
        print(f"  TTFT              : mean {1e3*float(np.mean(ttfts)):.1f} ms, "
              f"p max {1e3*float(np.max(ttfts)):.1f} ms")
    if stats.queue_wait.count:
        print(f"  queue wait        : p50 {1e3*stats.queue_wait.p50:.1f} ms, "
              f"p95 {1e3*stats.queue_wait.p95:.1f} ms over "
              f"{stats.queue_wait.count} admissions")
    if stats.itl.count:
        print(f"  ITL               : p50 {1e3*stats.itl.p50:.1f} ms, "
              f"p95 {1e3*stats.itl.p95:.1f} ms")
    reasons = {}
    for r in eng.finished.values():
        reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
    print(f"  finish reasons    : {reasons}")
    if args.cache_layout == "paged":
        kb = eng.kv_bytes()
        print(f"  KV pool           : {kb['allocated']/2**20:.2f} MiB allocated, "
              f"{kb['peak_in_use']/2**20:.2f} MiB peak in use "
              f"(kv_dtype={kb['kv_dtype']}, payload {kb['payload']/2**20:.2f} MiB)")
        print(f"  prefix cache      : {stats.prefix_hits} page hits / "
              f"{stats.prefix_misses} misses ({stats.prefix_hit_tokens} tokens reused)")
        print(f"  preemptions       : {stats.preemptions}  "
              f"admission blocks: {stats.admission_blocks}")
    if args.disagg:
        ho = eng.snapshot()["disagg"]["handoff"]
        print(f"  KV handoff        : {ho['segments']} segments "
              f"({ho['eager_segments']} eager), "
              f"{ho['bytes_shipped']/2**20:.2f} MiB shipped, "
              f"{ho['installs']} installs")
    if stats.swap_agg.count:
        print(f"  swap latency hidden by overlap: "
              f"{100*stats.swap_agg.mean_hidden_fraction:.0f}% (paper: ~75%); "
              f"mean exposed cost {1e3*stats.swap_agg.mean_cost:.2f} ms")
    drift = eng.snapshot().get("roofline_drift", {})
    for phase, d in drift.items():
        print(f"  roofline [{phase:>11}]: measured "
              f"{1e6*d['measured_s_per_token']:.2f} us/tok vs bound "
              f"{1e6*d['bound_s_per_token']:.3f} us/tok "
              f"(residency {d['residency_ratio']:.4f})")
    for rid in sorted(eng.finished)[:3]:
        print(f"  {rid}: {eng.finished[rid].out_tokens[:8]}...")
    if args.trace_out:
        trace = TRACER.export_chrome_trace(args.trace_out)
        print(f"  trace             : {len(trace['traceEvents'])} events -> "
              f"{args.trace_out} ({TRACER.dropped} dropped)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
