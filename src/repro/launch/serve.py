"""Cluster serving entrypoint: PD-Swap engine under a synthetic request load.

    python -m repro.launch.serve --arch smollm-135m --reduced \
        --requests 8 --mode pdswap

Drives the continuous-batching ServingEngine (the paper's single-RP temporal
logic swap, or the static TeLLMe-style baseline with --mode static) and
prints per-phase stats including the measured overlap of the swap.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, reduced_config
from repro.models import get_model
from repro.serving.engine import Request, ServingEngine


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ALL_ARCHS, default="smollm-135m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--mode", default="pdswap", choices=["pdswap", "static"])
    p.add_argument("--cache-layout", default="contiguous", choices=["contiguous", "paged"])
    p.add_argument("--block-size", type=int, default=16,
                   help="tokens per KV page (paged layout)")
    p.add_argument("--num-blocks", type=int, default=None,
                   help="KV pool pages (paged layout; default = full provisioning)")
    p.add_argument("--ragged", action="store_true",
                   help="draw prompt lengths uniformly in [4, prompt_len]")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--no-overlap", action="store_true",
                   help="serialize the swap after the prefill tail (ablation)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    assert cfg.family == "transformer", "serving engine drives the transformer family"
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(args.seed), dtype=jnp.float32)

    eng = ServingEngine(cfg, params, n_slots=args.slots, max_len=args.max_len,
                        prompt_len=args.prompt_len, mode=args.mode,
                        cache_layout=args.cache_layout, block_size=args.block_size,
                        num_blocks=args.num_blocks, overlap=not args.no_overlap)
    rng = np.random.default_rng(args.seed)
    ragged_lo = max(1, min(4, args.prompt_len))  # keep low < high for tiny prompt-len
    for i in range(args.requests):
        n = int(rng.integers(ragged_lo, args.prompt_len + 1)) if args.ragged else args.prompt_len
        prompt = rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
        eng.submit(Request(f"req-{i}", prompt, max_new=args.max_new))

    stats = eng.run()
    print(f"\nmode={args.mode} overlap={not args.no_overlap}")
    print(f"  requests finished : {len(eng.finished)}/{args.requests}")
    print(f"  prefill tokens    : {stats.prefill_tokens}  ({stats.t_prefill:.2f}s)")
    print(f"  decode tokens     : {stats.decode_tokens}  ({stats.t_decode:.2f}s, "
          f"{stats.decode_tput():.1f} tok/s on this host)")
    print(f"  logic swaps       : {stats.swaps}")
    if args.cache_layout == "paged":
        kb = eng.kv_bytes()
        print(f"  KV pool           : {kb['allocated']/2**20:.2f} MiB allocated, "
              f"{kb['peak_in_use']/2**20:.2f} MiB peak in use")
        print(f"  prefix cache      : {stats.prefix_hits} page hits / "
              f"{stats.prefix_misses} misses ({stats.prefix_hit_tokens} tokens reused)")
        print(f"  preemptions       : {stats.preemptions}  "
              f"admission blocks: {stats.admission_blocks}")
    hid = [t.hidden_fraction for t in stats.swap_timings if t.t_relayout or t.t_total_overlapped]
    if hid:
        print(f"  swap latency hidden by overlap: {100*float(np.mean(hid)):.0f}% (paper: ~75%)")
    for rid in sorted(eng.finished)[:3]:
        print(f"  {rid}: {eng.finished[rid].out_tokens[:8]}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
