"""Cluster training entrypoint with the fault-tolerance loop.

    python -m repro.launch.train --arch smollm-135m --steps 500 \
        --ckpt-dir /tmp/ckpt --mesh host

Fault-tolerance design (DESIGN.md §3):
  * async checkpoint every ``--ckpt-every`` steps (snapshot-to-host is
    synchronous, the write happens on a background thread — the step loop
    never stalls on storage);
  * crash-safe checkpoint format (tmp dir + atomic rename);
  * restart: ``--restore`` resumes from the latest complete checkpoint —
    params/optimizer are ``device_put`` against the CURRENT mesh, so a job
    can come back on a different device count (elastic shrink/grow);
  * the data pipeline is a pure function of (seed, step): restart-at-step-N
    is exact with zero bookkeeping;
  * in-process retry: a step that dies with a transient error (preemption
    signal, DMA failure) triggers restore-from-last-checkpoint and replay —
    the same loop a cluster scheduler runs across processes;
  * straggler mitigation: synchronous SPMD + re-mesh on restore is the
    framework's answer at this scale (per-step hedging cannot be expressed
    inside one XLA program; see DESIGN.md).

On the multi-host cluster this same file is launched per host with
``jax.distributed.initialize`` (env-driven); here it runs single-process.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ALL_ARCHS, get_config, reduced_config
from repro.data.pipeline import DataConfig, make_source
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.sharding_rules import params_shardings
from repro.optim.adamw import AdamWState, adamw_init
from repro.train.trainer import TrainConfig, init_train_state, jit_train_step


def build_mesh(kind: str):
    if kind == "none":
        return None
    if kind == "host":
        return make_host_mesh()
    return make_production_mesh(multi_pod=(kind == "multi"))


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ALL_ARCHS, default="smollm-135m")
    p.add_argument("--reduced", action="store_true", help="reduced same-family config (CPU)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--mesh", default="none", choices=["none", "host", "single", "multi"])
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--restore", action="store_true")
    p.add_argument("--max-retries", type=int, default=2)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    tcfg = TrainConfig(lr=args.lr, schedule=args.schedule, warmup=max(args.steps // 20, 5),
                       total_steps=args.steps, microbatches=args.microbatches)
    mesh = build_mesh(args.mesh)
    dcfg = DataConfig(batch=args.batch, seq_len=args.seq, vocab_size=cfg.vocab_size, seed=args.seed)
    source = make_source(dcfg)

    params, opt = init_train_state(cfg, jax.random.PRNGKey(args.seed), mesh, dtype=jnp.float32)
    step_fn = jit_train_step(cfg, tcfg, mesh, jax.eval_shape(lambda: params), donate=True)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr and args.restore and mgr.latest_step() is not None:
        psh = params_shardings(params, cfg, mesh, train=True) if mesh is not None else None
        osh = AdamWState(step=None, mu=psh, nu=psh) if psh is not None else None
        (params, opt), start = mgr.restore((params, opt), shardings=(psh, osh) if psh else None)
        print(f"[restore] resumed from step {start} (mesh={args.mesh})")

    def checkpoint(step, blocking=False):
        if not mgr:
            return
        mgr.save_async(step, (params, opt))
        if blocking:
            mgr.wait()

    step = start
    retries = 0
    t0 = time.time()
    while step < args.steps:
        try:
            batch = {k: jnp.asarray(v) for k, v in source.batch(step).items()}
            params, opt, metrics = step_fn(params, opt, batch, jnp.int32(step))
            if step % args.log_every == 0:
                loss = float(metrics["loss"])
                tput = dcfg.batch * dcfg.seq_len * max(step - start, 1) / (time.time() - t0)
                print(f"step {step:5d}  loss {loss:.4f}  lr {float(metrics['lr']):.2e}  "
                      f"{tput:,.0f} tok/s")
            step += 1
            if mgr and step % args.ckpt_every == 0:
                checkpoint(step)
        except KeyboardInterrupt:
            raise
        except Exception as e:  # transient failure -> restore & replay
            retries += 1
            print(f"[fault] step {step} failed ({e!r}); retry {retries}/{args.max_retries}")
            if retries > args.max_retries or mgr is None:
                raise
            mgr.wait()
            (params, opt), step = mgr.restore((params, opt))
            print(f"[fault] restored step {step}, replaying")

    if mgr:
        checkpoint(step, blocking=True)
        print(f"[done] final checkpoint at step {step} -> {mgr.dir}")
    final_loss = float(metrics["loss"]) if step > start else float("nan")
    print(f"[done] {step - start} steps in {time.time()-t0:.1f}s, final loss {final_loss:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
