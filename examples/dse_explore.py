"""Design-space exploration walkthrough (paper Eq. 2-6).

Runs the roofline-guided DSE for the paper's BitNet 0.73B and one assigned
arch, printing the feasible frontier and the chosen phase-RM configurations,
plus the static-baseline comparison the paper's Fig. 6 quantifies.

    PYTHONPATH=src python examples/dse_explore.py [--arch qwen2.5-14b]
"""
import argparse

from repro.configs import ALL_ARCHS, get_config
from repro.core.dse import best_config, run_dse


def explore(arch: str, top: int = 5):
    cfg = get_config(arch)
    if cfg.attention_free:
        print(f"{arch}: attention-free — no attention RM to size (phase split still applies)")
        return
    print(f"\n=== {arch} ===")
    pts = run_dse(cfg)
    print(f"{'feas':4s} {'blk':>5s} {'bk':>5s} {'tlmm':>13s} {'vmem KiB':>9s} "
          f"{'T_pre':>8s} {'T_dec(2k)':>9s} {'Eq6 obj':>8s}")
    for pt in pts[:top]:
        c = pt.config
        print(f"{'y' if pt.feasible else 'n':4s} {c.prefill_blk:5d} {c.decode_bk:5d} "
              f"{c.tlmm_bm}x{c.tlmm_bk}x{c.tlmm_bn:>4d} {pt.vmem_bytes/1024:9.0f} "
              f"{pt.t_pre:8.3f} {pt.t_dec_long:9.4f} {pt.objective:8.3f}")
    static = run_dse(cfg, static_baseline=True)
    sbest = next((x for x in static if x.feasible), static[0])
    best = next((x for x in pts if x.feasible), pts[0])
    print(f"swap objective {best.objective:.3f}s vs static-best {sbest.objective:.3f}s "
          f"-> logic swapping wins {sbest.objective/best.objective:.2f}x (Eq. 6, alpha=0.7)")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ALL_ARCHS, default="qwen2.5-14b")
    args = p.parse_args()
    explore("bitnet-730m")
    explore(args.arch)


if __name__ == "__main__":
    main()
