"""Quickstart: the PD-Swap mechanism end to end in ~60 lines.

Builds a tiny BitNet-style ternary transformer, runs the prefill phase
program, performs the latency-overlapped logic swap (prefill RM -> decode
RM, hiding the KV relayout under the prefill tail), then decodes tokens
with the bandwidth-optimized decode program.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.core.phase_engine import PhaseEngine
from repro.core.swap import SwapController
from repro.models import get_model


def main():
    # The paper's model family: ternary weights (W1.58), int8 activations.
    cfg = reduced_config("bitnet-730m", num_layers=4, d_model=256, vocab_size=1024)
    cfg = cfg.__class__(**{**cfg.__dict__})  # frozen dataclass copy
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

    prompt_len, max_len, n_new = 32, 96, 12
    tokens = (jnp.arange(prompt_len, dtype=jnp.int32) * 7 % cfg.vocab_size)[None]

    # Phase-specialized programs: the TPU analogue of the two reconfigurable
    # modules (prefill RM / decode RM) sharing one fabric budget.
    engine = PhaseEngine(cfg, mesh=None, max_len=max_len)
    body, tail = engine.prefill_split_programs(jax.eval_shape(lambda: params), 1, prompt_len)
    relayout = engine.relayout_program(1, prompt_len, max_len)
    decode = engine.decode_program(jax.eval_shape(lambda: params), 1, max_len)

    # --- prefill + logic swap (relayout overlapped with the prefill tail) ---
    ctl = SwapController(body.fn, tail.fn, relayout.fn)
    logits, cache, timing = ctl.prefill_and_swap(params, tokens, overlap=True)
    print(f"prefill+swap done: body {timing.t_body*1e3:.1f} ms, "
          f"tail||relayout {timing.t_tail*1e3:.1f} ms (overlapped)")

    # --- decode phase: one token per step against the streaming KV cache ---
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lengths = jnp.full((1,), prompt_len, jnp.int32)
    out = [int(tok[0])]
    t0 = time.perf_counter()
    for i in range(n_new - 1):
        logits, cache = decode.fn(params, tok, cache, lengths + i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    dt = time.perf_counter() - t0
    print(f"decoded {n_new} tokens: {out}")
    print(f"decode throughput on this host: {n_new/dt:.1f} tok/s "
          "(CPU functional run; see EXPERIMENTS.md for the v5e roofline)")


if __name__ == "__main__":
    main()
