"""End-to-end serving driver: PD-Swap vs static engine on batched requests.

The paper's headline experiment (Fig. 6) as a runnable program: the same
model and request stream served by (a) the PD-Swap engine — phase-
specialized prefill/decode programs, latency-overlapped logic swap — and
(b) the static TeLLMe-style engine.  Greedy outputs must match exactly;
timings on this host validate the mechanism (performance claims for the
TPU target come from the roofline benchmarks).

    PYTHONPATH=src python examples/serve_pdswap.py [--requests 8]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import get_model
from repro.serving.engine import Request, ServingEngine


def drive(mode, cfg, params, prompts, args):
    eng = ServingEngine(cfg, params, n_slots=args.slots, max_len=args.max_len,
                        prompt_len=args.prompt_len, mode=mode)
    for i, prompt in enumerate(prompts):
        eng.submit(Request(f"req-{i}", prompt, max_new=args.max_new))
    stats = eng.run()
    return eng, stats


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=24)
    p.add_argument("--max-new", type=int, default=12)
    p.add_argument("--max-len", type=int, default=64)
    args = p.parse_args()

    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=192, vocab_size=2048,
                         num_heads=6, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]

    eng_pd, st_pd = drive("pdswap", cfg, params, prompts, args)
    eng_st, st_st = drive("static", cfg, params, prompts, args)

    same = all(eng_pd.finished[k].out_tokens == eng_st.finished[k].out_tokens
               for k in eng_pd.finished)
    print(f"{'engine':8s} {'decode tok':>10s} {'decode tok/s':>12s} {'swaps':>6s} {'prefill s':>10s}")
    for name, st in (("pdswap", st_pd), ("static", st_st)):
        print(f"{name:8s} {st.decode_tokens:10d} {st.decode_tput():12.1f} "
              f"{st.swaps:6d} {st.t_prefill:10.2f}")
    hid = [t.hidden_fraction for t in st_pd.swap_timings if t.t_total_overlapped]
    if hid:
        print(f"swap overlap hid {100*float(np.mean(hid)):.0f}% of the relayout latency")
    print(f"greedy outputs identical across engines: {same}")
    assert same, "PD-Swap must be bit-identical to the static engine"


if __name__ == "__main__":
    main()
