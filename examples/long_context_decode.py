"""Long-context decode on the sub-quadratic architectures.

The ``long_500k`` cell (524,288-token context, batch 1) is only feasible for
architectures whose decode state is bounded: xlstm (O(1) recurrent state)
and hymba (sliding-window attention + SSM).  This example runs the decode
RMs of both at a reduced scale and shows the per-step cost is flat in
context length — the property the full-scale dry-run certifies at 500k.

    PYTHONPATH=src python examples/long_context_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.models import get_model


def run_arch(arch: str, ctx_lengths=(64, 256, 1024)):
    cfg = reduced_config(arch)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    print(f"\n{arch} ({cfg.family}): per-decode-step wall time vs context")
    for ctx in ctx_lengths:
        if cfg.family == "xlstm":
            cache = api.init_cache(cfg, 1)  # O(1) state — no KV buffer at all
        else:
            cache = api.init_cache(cfg, 1, ctx)
        lengths = jnp.full((1,), ctx - 1, jnp.int32)
        tok = jnp.zeros((1,), jnp.int32)
        step = jax.jit(lambda p, t, c, l: api.decode_step(p, t, c, l, cfg))
        logits, cache = step(params, tok, cache, lengths)  # compile
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for _ in range(5):
            logits, cache = step(params, tok, cache, lengths)
        jax.block_until_ready(logits)
        dt = (time.perf_counter() - t0) / 5
        state_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
        print(f"  ctx {ctx:6d}: {dt*1e3:7.2f} ms/step   state {state_bytes/2**20:7.2f} MiB")


def main():
    run_arch("xlstm-1.3b")
    run_arch("hymba-1.5b")
    print("\nfull-scale long_500k certification: results/dryrun/*long_500k*.json")


if __name__ == "__main__":
    main()
