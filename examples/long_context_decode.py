"""Long-context decode on the sub-quadratic architectures — and the
quantized-KV transformer.

The ``long_500k`` cell (524,288-token context, batch 1) is only feasible for
architectures whose decode state is bounded: xlstm (O(1) recurrent state)
and hymba (sliding-window attention + SSM).  This example runs the decode
RMs of both at a reduced scale and shows the per-step cost is flat in
context length — the property the full-scale dry-run certifies at 500k.

``--kv-dtype int8|int4`` additionally runs a transformer decode RM over the
*quantized* KV cache (packed payload + fp32 scale planes,
``repro.quant.kv_quant``): the state column shrinks 2x/4x, which is the
paper's Eq. (5) bandwidth lever at long context.

    PYTHONPATH=src python examples/long_context_decode.py --kv-dtype int4
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.models import get_model


def _state_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def run_arch(arch: str, ctx_lengths=(64, 256, 1024)):
    cfg = reduced_config(arch)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    print(f"\n{arch} ({cfg.family}): per-decode-step wall time vs context")
    for ctx in ctx_lengths:
        if cfg.family == "xlstm":
            cache = api.init_cache(cfg, 1)  # O(1) state — no KV buffer at all
        else:
            cache = api.init_cache(cfg, 1, ctx)
        lengths = jnp.full((1,), ctx - 1, jnp.int32)
        tok = jnp.zeros((1,), jnp.int32)
        step = jax.jit(lambda p, t, c, l: api.decode_step(p, t, c, l, cfg))
        logits, cache = step(params, tok, cache, lengths)  # compile
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for _ in range(5):
            logits, cache = step(params, tok, cache, lengths)
        jax.block_until_ready(logits)
        dt = (time.perf_counter() - t0) / 5
        print(f"  ctx {ctx:6d}: {dt*1e3:7.2f} ms/step   state {_state_bytes(cache)/2**20:7.2f} MiB")


def run_transformer_kv(arch: str, kv_dtype: str, ctx_lengths=(64, 256, 1024)):
    """Transformer decode RM over a (possibly quantized) contiguous cache:
    the KV state column is what ``kv_dtype`` shrinks."""
    from repro.models import transformer as T
    from repro.quant.kv_quant import payload_bytes

    cfg = reduced_config(arch)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    print(f"\n{arch} (transformer, kv_dtype={kv_dtype}): per-decode-step wall time vs context")
    for ctx in ctx_lengths:
        cache = T.init_cache(cfg, 1, ctx, kv_dtype=kv_dtype)
        lengths = jnp.full((1,), ctx - 1, jnp.int32)
        tok = jnp.zeros((1,), jnp.int32)
        step = jax.jit(lambda p, t, c, l: api.decode_step(p, t, c, l, cfg))
        logits, cache = step(params, tok, cache, lengths)  # compile
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for _ in range(5):
            logits, cache = step(params, tok, cache, lengths)
        jax.block_until_ready(logits)
        dt = (time.perf_counter() - t0) / 5
        print(f"  ctx {ctx:6d}: {dt*1e3:7.2f} ms/step   KV {_state_bytes(cache)/2**20:7.2f} MiB "
              f"(payload {payload_bytes(cache)/2**20:.2f} MiB)")


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--kv-dtype", default="fp", choices=["fp", "int8", "int4"],
                   help="KV-cache precision for the transformer long-context run")
    args = p.parse_args(argv)
    run_arch("xlstm-1.3b")
    run_arch("hymba-1.5b")
    run_transformer_kv("smollm-135m", args.kv_dtype)
    print("\nfull-scale long_500k certification: results/dryrun/*long_500k*.json")


if __name__ == "__main__":
    main()
