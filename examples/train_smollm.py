"""Train a smollm-family model end to end with the full training substrate.

Exercises: deterministic data pipeline, FSDPxTP-capable train step (here on
the host mesh), WSD/cosine schedules, async checkpointing, restart-exact
resume, and loss-goes-down validation.

    PYTHONPATH=src python examples/train_smollm.py            # ~10M params, 200 steps
    PYTHONPATH=src python examples/train_smollm.py --full     # the real 135M config
"""
import argparse
import tempfile

from repro.launch import train as train_cli


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true", help="full smollm-135m (slow on CPU)")
    p.add_argument("--steps", type=int, default=200)
    args = p.parse_args()

    ckpt = tempfile.mkdtemp(prefix="smollm_ckpt_")
    argv = [
        "--arch", "smollm-135m",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--schedule", "wsd",           # minicpm-style warmup-stable-decay
        "--ckpt-dir", ckpt, "--ckpt-every", "50",
        "--log-every", "20",
    ]
    if not args.full:
        argv.append("--reduced")
    rc = train_cli.main(argv)

    # restart-exact resume from the final checkpoint (fault-tolerance check)
    print("\n-- simulating restart: resume from latest checkpoint --")
    rc |= train_cli.main(argv + ["--restore", "--steps", str(args.steps + 20)])
    raise SystemExit(rc)


if __name__ == "__main__":
    main()
